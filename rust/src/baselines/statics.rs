//! Static-{Medium, Large} baselines: one fixed allocation for every
//! invocation of every function, routed by the default OpenWhisk
//! (memory-centric) scheduler — §7.1(1).

use crate::coordinator::scheduler::openwhisk::OpenWhiskScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::simulator::worker::Cluster;
use crate::simulator::{Decision, InvocationRecord, Policy, Request, SimTime};

#[derive(Debug)]
pub struct StaticPolicy {
    vcpus: u32,
    mem_mb: u32,
    scheduler: OpenWhiskScheduler,
    label: String,
}

impl StaticPolicy {
    pub fn new(label: &str, vcpus: u32, mem_mb: u32, seed: u64) -> Self {
        StaticPolicy {
            vcpus,
            mem_mb,
            scheduler: OpenWhiskScheduler::new(seed),
            label: label.to_string(),
        }
    }

    /// "Medium" static ask: 12 vCPUs / 3 GB (§7.1).
    pub fn medium(seed: u64) -> Self {
        Self::new("static-medium", 12, 3072, seed)
    }

    /// "Large" static ask: 20 vCPUs / 5 GB (§7.1).
    pub fn large(seed: u64) -> Self {
        Self::new("static-large", 20, 5120, seed)
    }
}

impl Policy for StaticPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
        let sched = self.scheduler.schedule(req, self.vcpus, self.mem_mb, cluster);
        Decision {
            worker: sched.worker,
            vcpus: self.vcpus,
            mem_mb: self.mem_mb,
            container: sched.container,
            background: None,
            overhead_s: sched.latency_s,
        }
    }

    fn on_complete(&mut self, _now: SimTime, _rec: &InvocationRecord, _cluster: &Cluster) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::functions::catalog::index_of;
    use crate::simulator::engine::simulate;
    use crate::simulator::SimConfig;

    #[test]
    fn every_invocation_gets_the_same_size() {
        let mut p = StaticPolicy::medium(1);
        let reqs: Vec<Request> = (0..10)
            .map(|i| {
                let mut input = InputSpec::new(InputKind::Payload);
                input.length = 100.0 * (i + 1) as f64;
                Request {
                    id: i + 1,
                    func: index_of("qr").unwrap(),
                    input,
                    arrival: i as f64,
                    slo_s: 1.0,
                }
            })
            .collect();
        let res = simulate(SimConfig::small(), &mut p, reqs);
        assert!(res.records.iter().all(|r| r.requested_vcpus == 12));
        assert!(res.records.iter().all(|r| r.requested_mem_mb == 3072));
    }

    #[test]
    fn large_bigger_than_medium() {
        let m = StaticPolicy::medium(1);
        let l = StaticPolicy::large(1);
        assert!(l.vcpus > m.vcpus && l.mem_mb > m.mem_mb);
    }
}
