//! Comparison systems (§7.1): two static baselines and three
//! state-of-the-art allocators, each implemented at the granularity the
//! paper's evaluation exercises.
//!
//! * Static-{Medium,Large} — fixed per-function allocation, default
//!   OpenWhisk resource management + scheduling.
//! * Parrotfish — offline parametric-regression developer tool; one
//!   (memory-bound, vCPU-coupled) allocation per function from two
//!   representative inputs.
//! * Aquatope — offline Bayesian-optimization-style search, decoupled
//!   resource types, uncertainty-aware over-provisioning; paired with
//!   Shabari's scheduler (as the paper does, §7.1(3)).
//! * Cypress — input-size-only linear regression for execution time,
//!   batch-oriented container provisioning, single-threaded assumption.

pub mod aquatope;
pub mod cypress;
pub mod parrotfish;
pub mod profiling;
pub mod statics;

pub use aquatope::AquatopePolicy;
pub use cypress::CypressPolicy;
pub use parrotfish::ParrotfishPolicy;
pub use statics::StaticPolicy;
