//! Aquatope (§7.1(3), ASPLOS'23): Bayesian-optimization resource manager
//! with **decoupled** vCPU/memory decisions but **input-agnostic**
//! per-function allocations. The paper supplies it the same two
//! representative inputs as Parrotfish, takes its predicted allocation
//! for all invocations of the function, and pairs it with Shabari's
//! scheduler (since Aquatope also decouples resource types).
//!
//! We model its noise/uncertainty-aware BO as an offline search over the
//! (vCPU, memory) grid that picks the cheapest configuration whose
//! *uncertainty-padded* execution time meets the SLO target for both
//! representative inputs — the padding is what makes Aquatope
//! systematically over-provision (3x p95 wasted vCPUs at low load,
//! Fig 8b).

use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::functions::catalog::CATALOG;
use crate::functions::inputs;
use crate::simulator::worker::Cluster;
use crate::simulator::{Decision, InvocationRecord, Policy, Request, SimTime};
use crate::util::rng::Rng;

use super::profiling;

/// Uncertainty padding factor on predicted execution time (BO's
/// exploration-safety margin).
const UNCERTAINTY_PAD: f64 = 1.25;
/// Memory safety factor above the observed footprint.
const MEM_PAD: f64 = 1.5;

#[derive(Debug, Clone, Copy)]
pub struct AquaAlloc {
    pub vcpus: u32,
    pub mem_mb: u32,
}

#[derive(Debug)]
pub struct AquatopePolicy {
    allocs: Vec<AquaAlloc>,
    scheduler: ShabariScheduler,
}

/// Salt decorrelating the offline BO-search stream from the run streams
/// sharing the same seed.
const SALT_AQUATOPE: u64 = 0xAA70_93E5;

impl AquatopePolicy {
    /// Offline BO-style phase. `slo_of` maps (func, input) to the SLO the
    /// search targets (the evaluation's per-input SLOs).
    pub fn offline(seed: u64, slo_of: impl Fn(usize, usize) -> f64) -> Self {
        let mut rng = Rng::new(seed ^ SALT_AQUATOPE);
        let mut allocs = Vec::with_capacity(CATALOG.len());
        for (fi, spec) in CATALOG.iter().enumerate() {
            let pool = inputs::pool(spec, &mut rng);
            let medium_idx = pool.len() / 2;
            let large_idx = pool.len() - 1;
            let (medium, large) = (&pool[medium_idx], &pool[large_idx]);
            let slo_m = slo_of(fi, medium_idx);
            let slo_l = slo_of(fi, large_idx);

            // memory: padded worst footprint of the representative inputs
            let need_gb = profiling::isolated_mem_gb(fi, large, 5, &mut rng)
                .max(profiling::isolated_mem_gb(fi, medium, 5, &mut rng));
            let mem_mb = (((need_gb * MEM_PAD * 1024.0) / 128.0).ceil() * 128.0) as u32;

            // vCPUs: smallest count whose padded time meets both SLOs
            let mut vcpus = 48;
            for k in 1..=48u32 {
                let t_m = profiling::isolated_exec_s(fi, medium, k, 5, &mut rng);
                let t_l = profiling::isolated_exec_s(fi, large, k, 5, &mut rng);
                if t_m * UNCERTAINTY_PAD <= slo_m && t_l * UNCERTAINTY_PAD <= slo_l {
                    vcpus = k;
                    break;
                }
            }
            allocs.push(AquaAlloc { vcpus, mem_mb: mem_mb.clamp(256, 6144) });
        }
        AquatopePolicy { allocs, scheduler: ShabariScheduler::new(seed) }
    }

    pub fn allocation(&self, func: usize) -> AquaAlloc {
        self.allocs[func]
    }
}

impl Policy for AquatopePolicy {
    fn name(&self) -> String {
        "aquatope".to_string()
    }

    fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
        let a = self.allocs[req.func];
        let sched = self.scheduler.schedule(req, a.vcpus, a.mem_mb, cluster);
        Decision {
            worker: sched.worker,
            vcpus: a.vcpus,
            mem_mb: a.mem_mb,
            container: sched.container,
            background: sched.background,
            overhead_s: sched.latency_s,
        }
    }

    fn on_complete(&mut self, _now: SimTime, _rec: &InvocationRecord, _cluster: &Cluster) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::catalog::index_of;

    fn policy() -> AquatopePolicy {
        // generous SLOs: 1.4x the 8-vCPU isolated time
        AquatopePolicy::offline(1, |fi, ii| {
            let mut rng = Rng::new(99);
            let pool = inputs::pool(&CATALOG[fi], &mut rng);
            let mut r2 = Rng::new(100);
            profiling::isolated_exec_s(fi, &pool[ii], 8, 3, &mut r2) * 1.4
        })
    }

    #[test]
    fn decoupled_and_padded() {
        let p = policy();
        // single-threaded functions: vCPUs low even though memory varies
        let qr = p.allocation(index_of("qr").unwrap());
        assert!(qr.vcpus <= 4, "single-threaded needs few vCPUs, got {}", qr.vcpus);
        let sent = p.allocation(index_of("sentiment").unwrap());
        assert!(sent.mem_mb >= 4096, "padded memory for sentiment, got {}", sent.mem_mb);
    }

    #[test]
    fn overprovisions_vs_need() {
        // the BO pad makes allocations exceed what the SLO strictly needs
        let p = policy();
        let mm = p.allocation(index_of("matmult").unwrap());
        assert!(mm.vcpus >= 8, "large matrices at padded SLO need many cores, got {}", mm.vcpus);
    }

    #[test]
    fn allocation_is_input_agnostic() {
        let p = policy();
        // one allocation per function by construction
        assert_eq!(p.allocs.len(), CATALOG.len());
    }
}
