//! Parrotfish (§7.1(2), SoCC'23): an offline developer tool that fits a
//! parametric cost model per function from sample runs and recommends a
//! single *memory* size minimizing developer cost; vCPUs are **coupled**
//! to memory AWS-Lambda-style (1 vCPU per 1769 MB). All invocations of a
//! function then use that one size, scheduled by default OpenWhisk.
//!
//! The paper gives it two representative inputs (medium + large) per
//! function. Its objective is $-cost (mem × time), not SLOs — which is
//! why it under-allocates multi-threaded functions and over-allocates
//! memory to buy vCPUs (Fig 8 analysis).

use crate::coordinator::scheduler::openwhisk::OpenWhiskScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::functions::catalog::CATALOG;
use crate::functions::inputs;
use crate::simulator::worker::Cluster;
use crate::simulator::{Decision, InvocationRecord, Policy, Request, SimTime};
use crate::util::rng::Rng;

use super::profiling;

/// AWS-Lambda-style coupling: one vCPU per this many MB.
pub const MB_PER_VCPU: f64 = 1769.0;

/// Per-function fixed recommendation.
#[derive(Debug, Clone, Copy)]
pub struct Recommendation {
    pub mem_mb: u32,
    pub vcpus: u32,
}

#[derive(Debug)]
pub struct ParrotfishPolicy {
    recs: Vec<Recommendation>,
    scheduler: OpenWhiskScheduler,
}

/// Salt decorrelating the offline-profiling stream from the run streams
/// sharing the same seed.
const SALT_PARROTFISH: u64 = 0x9A44_07F1;

impl ParrotfishPolicy {
    /// Offline phase: profile each function on two representative inputs
    /// across the memory ladder; pick the cheapest configuration
    /// (GB-seconds cost model, like the real tool).
    pub fn offline(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ SALT_PARROTFISH);
        let mut recs = Vec::with_capacity(CATALOG.len());
        for (fi, spec) in CATALOG.iter().enumerate() {
            let pool = inputs::pool(spec, &mut rng);
            let (medium, large) = profiling::representative_inputs(&pool);
            // memory ladder: 512 MB .. 6 GB in 512 MB steps
            let mut best: Option<(f64, u32)> = None;
            for step in 1..=12u32 {
                let mem_mb = step * 512;
                let vcpus = ((mem_mb as f64 / MB_PER_VCPU).ceil() as u32).max(1);
                // must fit both representative inputs' footprints
                let need_gb = profiling::isolated_mem_gb(fi, large, 5, &mut rng)
                    .max(profiling::isolated_mem_gb(fi, medium, 5, &mut rng));
                if (mem_mb as f64) < need_gb * 1024.0 {
                    continue;
                }
                let t_m = profiling::isolated_exec_s(fi, medium, vcpus, 5, &mut rng);
                let t_l = profiling::isolated_exec_s(fi, large, vcpus, 5, &mut rng);
                // GB-second billing cost, averaged over the two inputs
                let cost = (mem_mb as f64 / 1024.0) * (t_m + t_l) / 2.0;
                if best.map_or(true, |(c, _)| cost < c) {
                    best = Some((cost, mem_mb));
                }
            }
            let mem_mb = best.map(|(_, m)| m).unwrap_or(6144);
            let vcpus = ((mem_mb as f64 / MB_PER_VCPU).ceil() as u32).max(1);
            recs.push(Recommendation { mem_mb, vcpus });
        }
        ParrotfishPolicy { recs, scheduler: OpenWhiskScheduler::new(seed) }
    }

    pub fn recommendation(&self, func: usize) -> Recommendation {
        self.recs[func]
    }
}

impl Policy for ParrotfishPolicy {
    fn name(&self) -> String {
        "parrotfish".to_string()
    }

    fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
        let rec = self.recs[req.func];
        let sched = self.scheduler.schedule(req, rec.vcpus, rec.mem_mb, cluster);
        Decision {
            worker: sched.worker,
            vcpus: rec.vcpus,
            mem_mb: rec.mem_mb,
            container: sched.container,
            background: None,
            overhead_s: sched.latency_s,
        }
    }

    fn on_complete(&mut self, _now: SimTime, _rec: &InvocationRecord, _cluster: &Cluster) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::catalog::index_of;

    #[test]
    fn recommendations_exist_for_all_functions() {
        let p = ParrotfishPolicy::offline(1);
        for fi in 0..CATALOG.len() {
            let r = p.recommendation(fi);
            assert!(r.mem_mb >= 512 && r.mem_mb <= 6144, "{}", CATALOG[fi].name);
            assert!(r.vcpus >= 1);
        }
    }

    #[test]
    fn vcpus_coupled_to_memory() {
        let p = ParrotfishPolicy::offline(1);
        for fi in 0..CATALOG.len() {
            let r = p.recommendation(fi);
            assert_eq!(r.vcpus, ((r.mem_mb as f64 / MB_PER_VCPU).ceil() as u32).max(1));
        }
    }

    #[test]
    fn memory_covers_large_input_footprint() {
        // sentiment's large batch needs ~3.8 GB; parrotfish profiles it
        let p = ParrotfishPolicy::offline(1);
        let r = p.recommendation(index_of("sentiment").unwrap());
        assert!(r.mem_mb >= 3584, "got {}", r.mem_mb);
    }

    #[test]
    fn multithreaded_functions_get_few_vcpus() {
        // cost-optimal memory rarely buys many coupled vCPUs — the paper's
        // core criticism (poor SLO compliance for parallel functions)
        let p = ParrotfishPolicy::offline(1);
        let r = p.recommendation(index_of("matmult").unwrap());
        assert!(r.vcpus <= 4, "parrotfish under-allocates vCPUs, got {}", r.vcpus);
    }
}
