//! Offline profiling substrate shared by the baselines and the SLO
//! derivation: run a function/input in isolation (no contention, idle
//! NIC) at a given vCPU count and report execution time / utilization —
//! what the paper does on the real testbed to configure Parrotfish,
//! Aquatope, and the per-input SLOs (§7.1).

use crate::featurizer::InputSpec;
use crate::functions::catalog::CATALOG;
use crate::util::rng::Rng;
use crate::util::stats;

/// Median isolated execution time over `runs` noisy executions.
pub fn isolated_exec_s(func: usize, input: &InputSpec, vcpus: u32, runs: usize, rng: &mut Rng) -> f64 {
    let spec = &CATALOG[func];
    let times: Vec<f64> = (0..runs)
        .map(|_| {
            let d = spec.noisy_demand(input, rng);
            d.ideal_exec_s(vcpus as f64, 10.0)
        })
        .collect();
    stats::median(&times)
}

/// Median peak memory footprint (GB) over `runs` noisy executions.
pub fn isolated_mem_gb(func: usize, input: &InputSpec, runs: usize, rng: &mut Rng) -> f64 {
    let spec = &CATALOG[func];
    let peaks: Vec<f64> = (0..runs)
        .map(|_| spec.noisy_demand(input, rng).mem_gb)
        .collect();
    // use the max (a profiling tool sizes for the worst case it saw)
    peaks.into_iter().fold(0.0, f64::max)
}

/// The two "representative inputs" (medium and large) the paper hands to
/// Parrotfish and Aquatope: the middle and last entries of the pool.
pub fn representative_inputs(pool: &[InputSpec]) -> (&InputSpec, &InputSpec) {
    let medium = &pool[pool.len() / 2];
    let large = &pool[pool.len() - 1];
    (medium, large)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::catalog::index_of;
    use crate::functions::inputs;

    #[test]
    fn more_cores_never_hurt_isolated_time() {
        let fi = index_of("compress").unwrap();
        let mut rng = Rng::new(1);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let input = &pool[pool.len() - 1];
        let t4 = isolated_exec_s(fi, input, 4, 5, &mut Rng::new(2));
        let t16 = isolated_exec_s(fi, input, 16, 5, &mut Rng::new(2));
        assert!(t16 < t4);
    }

    #[test]
    fn representative_inputs_ordering() {
        let fi = index_of("compress").unwrap();
        let mut rng = Rng::new(1);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let (m, l) = representative_inputs(&pool);
        assert!(l.size_bytes > m.size_bytes);
    }

    #[test]
    fn mem_profile_covers_footprint() {
        let fi = index_of("sentiment").unwrap();
        let mut rng = Rng::new(1);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let gb = isolated_mem_gb(fi, &pool[pool.len() - 1], 8, &mut rng);
        assert!(gb > 3.0, "large sentiment batch footprint, got {gb}");
    }
}
