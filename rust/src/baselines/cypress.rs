//! Cypress (§7.1(4), SoCC'22): input **size**-aware container
//! provisioning with request batching.
//!
//! Faithful-to-the-evaluation model:
//! * per-function online linear regression `exec_time ≈ a·size + b`
//!   (size is the *only* input property it looks at — the §2.1 critique);
//! * assumes functions are single-threaded: every container gets a small
//!   fixed vCPU count;
//! * provisions containers for a *batch*: a container is sized to hold
//!   `B = max(1, floor(slack_window / predicted_exec))` queued
//!   invocations of similar slack, so its memory is `B ×` the
//!   per-invocation footprint estimate. Under the sparse arrivals of
//!   real serverless traffic, most containers end up holding a single
//!   invocation — the memory-waste failure mode of Fig 8c/8e.

use std::collections::BTreeMap;

use crate::coordinator::scheduler::openwhisk::OpenWhiskScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::simulator::worker::Cluster;
use crate::simulator::{ContainerChoice, Decision, InvocationRecord, Policy, Request, SimTime};

/// vCPUs per container (Cypress's single-threaded assumption).
const CYPRESS_VCPUS: u32 = 2;
/// Cap on the batch size a container is provisioned for.
const MAX_BATCH: u32 = 8;

/// Simple online simple-linear-regression (exec vs size).
#[derive(Debug, Clone, Default)]
struct SizeRegression {
    n: f64,
    sx: f64,
    sy: f64,
    sxx: f64,
    sxy: f64,
}

impl SizeRegression {
    fn add(&mut self, size_mb: f64, exec_s: f64) {
        self.n += 1.0;
        self.sx += size_mb;
        self.sy += exec_s;
        self.sxx += size_mb * size_mb;
        self.sxy += size_mb * exec_s;
    }

    fn predict(&self, size_mb: f64) -> Option<f64> {
        if self.n < 3.0 {
            return None;
        }
        let denom = self.n * self.sxx - self.sx * self.sx;
        if denom.abs() < 1e-9 {
            return Some(self.sy / self.n);
        }
        let a = (self.n * self.sxy - self.sx * self.sy) / denom;
        let b = (self.sy - a * self.sx) / self.n;
        Some((a * size_mb + b).max(0.01))
    }
}

#[derive(Debug)]
pub struct CypressPolicy {
    regressions: BTreeMap<usize, SizeRegression>,
    /// Running max footprint per function (per-invocation memory unit).
    mem_unit_mb: BTreeMap<usize, u32>,
    scheduler: OpenWhiskScheduler,
}

impl CypressPolicy {
    pub fn new(seed: u64) -> Self {
        CypressPolicy {
            regressions: BTreeMap::new(),
            mem_unit_mb: BTreeMap::new(),
            scheduler: OpenWhiskScheduler::new(seed),
        }
    }

    fn batch_size(&self, req: &Request) -> u32 {
        let size_mb = req.input.size_bytes / (1024.0 * 1024.0);
        match self.regressions.get(&req.func).and_then(|r| r.predict(size_mb)) {
            Some(pred) => ((req.slo_s / pred).floor() as u32).clamp(1, MAX_BATCH),
            None => 2, // bootstrap batch assumption
        }
    }
}

impl Policy for CypressPolicy {
    fn name(&self) -> String {
        "cypress".to_string()
    }

    fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
        let unit = *self.mem_unit_mb.get(&req.func).unwrap_or(&1024);
        let batch = self.batch_size(req);
        let mem_mb = (unit * batch).clamp(256, 6144);
        let vcpus = CYPRESS_VCPUS;

        // pack into an existing (batch-sized) warm container when one
        // fits — probed warm-bind-aware, so under reservation-holding
        // keep-alive the candidate's own reservation cannot veto its
        // capacity-neutral reuse (identical to has_capacity otherwise)
        let (worker, container) = match cluster.find_warm_larger(req.func, vcpus, mem_mb) {
            Some((w, cid)) if cluster.worker(w).has_capacity_for_warm(vcpus, mem_mb) => {
                (w, ContainerChoice::Warm(cid))
            }
            _ => {
                let sched = self.scheduler.schedule(req, vcpus, mem_mb, cluster);
                (sched.worker, sched.container)
            }
        };
        Decision {
            worker,
            vcpus,
            mem_mb,
            container,
            background: None,
            overhead_s: 0.001,
        }
    }

    fn on_complete(&mut self, _now: SimTime, rec: &InvocationRecord, _cluster: &Cluster) {
        let size_mb = rec.input.size_bytes / (1024.0 * 1024.0);
        self.regressions
            .entry(rec.func)
            .or_default()
            .add(size_mb, rec.exec_s);
        let used_mb = (rec.mem_used_gb * 1024.0).ceil() as u32;
        let e = self.mem_unit_mb.entry(rec.func).or_insert(1024);
        *e = (*e).max(((used_mb + 127) / 128) * 128);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::functions::catalog::index_of;
    use crate::simulator::engine::simulate;
    use crate::simulator::SimConfig;

    #[test]
    fn regression_learns_linear_fit() {
        let mut r = SizeRegression::default();
        for i in 1..=10 {
            r.add(i as f64, 2.0 * i as f64 + 1.0);
        }
        let p = r.predict(20.0).unwrap();
        assert!((p - 41.0).abs() < 1e-6, "got {p}");
    }

    #[test]
    fn prediction_needs_samples() {
        let mut r = SizeRegression::default();
        r.add(1.0, 1.0);
        assert!(r.predict(1.0).is_none());
    }

    #[test]
    fn always_small_vcpu_allocation() {
        let mut p = CypressPolicy::new(1);
        let reqs: Vec<Request> = (0..20)
            .map(|i| {
                let mut input = InputSpec::new(InputKind::File);
                input.id = i + 1;
                input.size_bytes = 2e9;
                Request {
                    id: i + 1,
                    func: index_of("compress").unwrap(),
                    input,
                    arrival: i as f64 * 5.0,
                    slo_s: 30.0,
                }
            })
            .collect();
        let res = simulate(SimConfig::small(), &mut p, reqs);
        assert!(
            res.records.iter().all(|r| r.requested_vcpus == CYPRESS_VCPUS),
            "cypress assumes single-threaded functions"
        );
        // multi-threaded compress at 2 vCPUs blows its SLO
        let viol = res.records.iter().filter(|r| r.slo_violated()).count();
        assert!(viol > res.records.len() / 2, "starved compress must violate, got {viol}");
    }

    #[test]
    fn batches_inflate_memory_under_sparse_arrivals() {
        let mut p = CypressPolicy::new(1);
        // short, predictable function with a relaxed SLO -> large batches
        let reqs: Vec<Request> = (0..30)
            .map(|i| {
                let mut input = InputSpec::new(InputKind::Payload);
                input.length = 200.0;
                input.size_bytes = 200.0;
                Request {
                    id: i + 1,
                    func: index_of("qr").unwrap(),
                    input,
                    arrival: i as f64 * 10.0, // sparse!
                    slo_s: 2.0,
                }
            })
            .collect();
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let recs = res.sorted_records();
        // after the regression warms up, containers are provisioned for
        // multi-invocation batches that sparse arrivals never fill
        let late = &recs[10..];
        let avg_util: f64 =
            late.iter().map(|r| r.mem_utilization()).sum::<f64>() / late.len() as f64;
        assert!(
            avg_util < 0.5,
            "sparse arrivals must waste batched memory, got util {avg_util}"
        );
    }
}
