//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no registry access, so the workspace vendors
//! the slice of `anyhow` it actually uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait. Semantics mirror the real crate where it matters here:
//!
//! * `{}` displays the outermost message, `{:#}` the full context chain
//!   joined with `": "`;
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (which is also why [`Error`] itself does not
//!   implement `std::error::Error` — exactly like the real `anyhow`);
//! * `with_context`/`context` push an outer message onto the chain.
//!
//! Swapping back to the real crate is a one-line change in
//! `rust/Cargo.toml`; no call site depends on anything beyond this subset.

use std::fmt;

/// A lightweight error: an ordered context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (for tests/inspection).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias: the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (or a single message).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn with_context_chains() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "writing table").unwrap_err();
        assert_eq!(format!("{e}"), "writing table");
        assert!(format!("{e:#}").starts_with("writing table: "));
    }

    #[test]
    fn ensure_formats() {
        fn check(x: u32) -> Result<()> {
            ensure!(x > 3, "x too small: {x}");
            Ok(())
        }
        assert!(check(5).is_ok());
        assert_eq!(format!("{}", check(1).unwrap_err()), "x too small: 1");
    }

    #[test]
    fn inline_captures_in_messages() {
        let name = "qr";
        let e = anyhow!("unknown function '{name}'");
        assert_eq!(e.to_string(), "unknown function 'qr'");
    }
}
