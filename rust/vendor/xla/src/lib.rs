//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The shabari crate's `xla` feature compiles `runtime::XlaEngine` and
//! `learner::xla::XlaCsmc` against this API surface. The stub keeps the
//! types and signatures of the real bindings for every call site in the
//! workspace, but any operation that would need libxla/PJRT returns a
//! runtime [`Error`] — so `cargo build --features xla` succeeds on a
//! machine without the PJRT shared libraries, and the failure mode is a
//! clear error at engine-load time instead of a link error.
//!
//! To run the real production path, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual xla-rs checkout (xla_extension 0.5.x);
//! host-side literal bookkeeping here matches its semantics.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring xla-rs's (stringly, Display + std::error::Error).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} needs the real PJRT runtime (this build vendors \
         rust/vendor/xla; see rust/Cargo.toml to link the real xla-rs)"
    ))
}

/// Element types a [`Literal`] can expose through [`Literal::to_vec`].
pub trait NativeType: Copy {}

impl NativeType for f32 {}

/// A host-side literal: flat f32 storage plus a shape.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret the literal with new dimensions (element count must
    /// match, as in the real bindings).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Overwrite the literal's contents in place (hot-path upload).
    pub fn copy_raw_from(&mut self, data: &[f32]) -> Result<()> {
        if data.len() != self.data.len() {
            return Err(Error(format!(
                "copy_raw_from: literal holds {} elements, got {}",
                self.data.len(),
                data.len()
            )));
        }
        self.data.copy_from_slice(data);
        Ok(())
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Flatten to a host vector of the given element type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// The literal's shape (stub-local helper, also present upstream).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; result is per-device, per-output.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[0.0; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        assert_eq!(l.reshape(&[3, 2]).unwrap().dims(), &[3, 2]);
    }

    #[test]
    fn copy_raw_checks_len() {
        let mut l = Literal::vec1(&[0.0; 4]);
        assert!(l.copy_raw_from(&[1.0, 2.0, 3.0, 4.0]).is_ok());
        assert!(l.copy_raw_from(&[1.0]).is_err());
    }

    #[test]
    fn runtime_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
