//! Engine scale throughput: the `experiment scale` grid (64 workers at
//! 4x the fig8 request rate) through the bench harness, so `cargo bench`
//! exercises the indexed warm-pool + cached-rate hot path at size.
//!
//! §Perf target: ≥3x the pre-index engine's wall-clock on this grid
//! (EXPERIMENTS.md §Perf records measured before/after numbers; the
//! canonical JSON dump comes from `make bench-scale`).

use shabari::experiments::common::Ctx;
use shabari::experiments::scale::run_scale;

fn main() {
    // Shorter trace than the canonical `make bench-scale` run so the
    // bench suite stays interactive; same cluster size and load.
    let ctx = Ctx { duration_s: 120.0, ..Default::default() };
    println!(
        "### engine scale ({} workers @ {} rps, {}s trace)",
        ctx.scale_workers, ctx.scale_rps, ctx.duration_s
    );
    let rows = run_scale(&ctx).expect("scale grid");
    for r in &rows {
        println!(
            "{:<22} {:>6} invocations  {:>8.2}s wall  {:>10.0} sim-inv/s  ({} containers)",
            r.policy,
            r.invocations,
            r.wall_s,
            r.sim_inv_per_s,
            r.metrics.containers_created
        );
    }
}
