//! Learner hot-path benchmarks (Fig 14's predict/update overheads):
//! native mirror vs the AOT XLA/PJRT production path, single + batched.
//! The XLA half needs a `--features xla` build plus `make artifacts`.

use shabari::learner::native::NativeCsmc;
use shabari::learner::{cost_vector, CsmcModel};
use shabari::runtime::{FEAT_DIM, NUM_CLASSES};
use shabari::util::bench;

fn x_vec(seed: f32) -> [f32; FEAT_DIM] {
    let mut x = [0f32; FEAT_DIM];
    for (j, v) in x.iter_mut().enumerate() {
        *v = ((j as f32 + seed) * 0.37).sin();
    }
    x[0] = 1.0;
    x
}

fn main() {
    bench::section("learner: native CSOAA (48 classes x 16 features)");
    let mut native = NativeCsmc::new(0.3);
    let x = x_vec(1.0);
    let costs = cost_vector(12, 2.0);
    bench::run_batched("native predict", 100, 200, 100, || {
        bench::keep(native.scores(&x));
    });
    bench::run_batched("native update", 100, 200, 100, || {
        native.update(&x, &costs);
    });

    xla_benches(&x, &costs);
}

#[cfg(not(feature = "xla"))]
fn xla_benches(_x: &[f32; FEAT_DIM], _costs: &[f32; NUM_CLASSES]) {
    println!("(skipping XLA benches: built without the `xla` feature)");
}

#[cfg(feature = "xla")]
fn xla_benches(x: &[f32; FEAT_DIM], costs: &[f32; NUM_CLASSES]) {
    use shabari::learner::xla::XlaCsmc;
    use shabari::runtime::{XlaEngine, BATCH};

    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("(skipping XLA benches: run `make artifacts` first)");
        return;
    }
    bench::section("learner: XLA/PJRT (AOT Pallas/JAX artifacts)");
    let engine = std::rc::Rc::new(XlaEngine::load_dir(artifacts).expect("artifacts"));
    let mut xla = XlaCsmc::new(engine, 0.3);
    // warm the executable caches
    for _ in 0..50 {
        bench::keep(xla.scores(x));
    }
    bench::run("xla predict", 50, 1000, || {
        bench::keep(xla.scores(x));
    });
    bench::run("xla update", 50, 1000, || {
        xla.update(x, costs);
    });

    let xs: Vec<f32> = (0..BATCH).flat_map(|i| x_vec(i as f32)).collect();
    let r = bench::run("xla predict_batch (B=64)", 20, 500, || {
        bench::keep(xla.scores_batch(&xs).unwrap());
    });
    println!(
        "  -> per-example amortized: {}",
        bench::fmt_ns(r.mean_ns / BATCH as f64)
    );
    println!("  (paper fig14: predict 2-4 ms, update 4-5 ms on their shim)");
}
