//! Scheduler decision latency (Fig 14: 0.5-1.5 ms on the paper's
//! 16-invoker cluster) under empty, warm-rich, and loaded cluster states.

use shabari::coordinator::scheduler::hermod::HermodScheduler;
use shabari::coordinator::scheduler::openwhisk::OpenWhiskScheduler;
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::scheduler::Scheduler;
use shabari::featurizer::{InputKind, InputSpec};
use shabari::functions::catalog::index_of;
use shabari::simulator::container::Container;
use shabari::simulator::worker::Cluster;
use shabari::simulator::{Request, SimConfig};
use shabari::util::bench;
use shabari::util::rng::Rng;

fn request() -> Request {
    Request {
        id: 1,
        func: index_of("qr").unwrap(),
        input: InputSpec::new(InputKind::Payload),
        arrival: 0.0,
        slo_s: 1.0,
    }
}

fn warm_cluster(n_containers: usize) -> Cluster {
    let mut cluster = Cluster::new(&SimConfig::default());
    let mut rng = Rng::new(7);
    for id in 1..=n_containers as u64 {
        let func = rng.below(12);
        let vcpus = rng.range_usize(1, 32) as u32;
        let mem = (rng.range_usize(2, 32) as u32) * 128;
        let w = rng.below(cluster.len());
        let mut c = Container::new(id, func, vcpus, mem, 0.0);
        c.mark_ready(0.0);
        cluster.insert_container(w, c);
    }
    cluster
}

fn main() {
    let req = request();

    bench::section("scheduler: shabari (16 workers)");
    let empty = Cluster::new(&SimConfig::default());
    let mut s = ShabariScheduler::new(1);
    bench::run_batched("shabari / empty cluster", 50, 200, 50, || {
        bench::keep(s.schedule(&req, 4, 512, &empty));
    });

    let warm = warm_cluster(200);
    bench::run_batched("shabari / 200 warm containers", 50, 200, 50, || {
        bench::keep(s.schedule(&req, 4, 512, &warm));
    });

    let warm_big = warm_cluster(2000);
    bench::run_batched("shabari / 2000 warm containers", 50, 200, 50, || {
        bench::keep(s.schedule(&req, 4, 512, &warm_big));
    });

    bench::section("scheduler: baselines");
    let mut ow = OpenWhiskScheduler::new(1);
    bench::run_batched("openwhisk / 200 warm", 50, 200, 50, || {
        bench::keep(ow.schedule(&req, 4, 512, &warm));
    });
    let mut hermod = HermodScheduler::new(1);
    bench::run_batched("hermod / 200 warm", 50, 200, 50, || {
        bench::keep(hermod.schedule(&req, 4, 512, &warm));
    });
    println!("  (paper fig14: 0.5-1.5 ms)");
}
