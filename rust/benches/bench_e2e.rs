//! End-to-end driver throughput: simulated invocations per wall-second
//! for the full stack (trace → coordinator → DES cluster → metrics) —
//! one bench per Fig-8 system, plus Shabari on the XLA production path.
//!
//! §Perf target: the native-learner coordinator must sustain >= 10^4
//! simulated invocations/s so full fig8 sweeps stay interactive.

use std::time::Instant;

use shabari::experiments::common::{make_policy, sim_config, Ctx};
use shabari::learner::xla::Backend;
use shabari::simulator::engine::simulate;

fn bench_policy(name: &str, ctx: &Ctx, rps: f64) {
    let w = ctx.workload();
    let cfg = sim_config(ctx);
    let trace = w.trace(rps, ctx.duration_s, 31);
    let n = trace.len();
    let mut policy = make_policy(name, ctx, &w).unwrap();
    let t0 = Instant::now();
    let res = simulate(cfg, &mut policy, trace);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<22} {:>6} invocations  {:>8.2}s wall  {:>10.0} sim-inv/s  ({} containers)",
        name,
        n,
        wall,
        n as f64 / wall,
        res.containers_created
    );
}

fn main() {
    println!("### e2e driver throughput (600 s trace @ 5 rps, 16 workers)");
    let ctx = Ctx { duration_s: 600.0, ..Default::default() };
    for name in ["shabari", "static-large", "parrotfish", "cypress", "aquatope"] {
        bench_policy(name, &ctx, 5.0);
    }

    if cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n### shabari with the XLA/PJRT learner (production path)");
        let ctx = Ctx {
            duration_s: 600.0,
            backend: Backend::Xla,
            ..Default::default()
        };
        bench_policy("shabari", &ctx, 5.0);
    } else {
        println!("(skipping XLA e2e: needs a --features xla build and `make artifacts`)");
    }
}
