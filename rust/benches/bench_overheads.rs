//! Fig-14 micro-overheads: featurization per input type, cost-function
//! evaluation, feature-cache hit path.

use shabari::coordinator::allocator::cost::{self, SlackPolicy};
use shabari::featurizer::{self, FeatureCache, InputKind, InputSpec};
use shabari::functions::catalog::CATALOG;
use shabari::functions::inputs;
use shabari::simulator::{InvocationRecord, Verdict};
use shabari::util::bench;
use shabari::util::rng::Rng;

fn main() {
    bench::section("featurizer: extraction compute per input type");
    let mut rng = Rng::new(3);
    for kind in InputKind::all() {
        // pick a representative input of this kind from the catalog pools
        let spec = CATALOG.iter().find(|f| f.input_kind == *kind);
        let input = match spec {
            Some(f) => inputs::pool(f, &mut rng)[2].clone(),
            None => {
                let mut s = InputSpec::new(*kind);
                s.size_bytes = 1e6;
                s.length = 500.0;
                s
            }
        };
        bench::run_batched(&format!("featurize {}", kind.name()), 100, 100, 100, || {
            bench::keep(featurizer::featurize(&input));
        });
    }

    bench::section("feature cache");
    let f = &CATALOG[2]; // imageprocess
    let input = inputs::pool(f, &mut rng)[3].clone();
    let mut cache = FeatureCache::new();
    cache.persist(&input);
    bench::run_batched("cache hit", 100, 100, 100, || {
        bench::keep(cache.featurize_invocation(&input));
    });

    bench::section("cost function");
    let rec = InvocationRecord {
        id: 1,
        func: 0,
        input: InputSpec::new(InputKind::Payload),
        worker: 0,
        vcpus: 16,
        mem_mb: 4096,
        requested_vcpus: 16,
        requested_mem_mb: 4096,
        arrival: 0.0,
        cold_start_s: 0.0,
        had_cold_start: false,
        overhead_s: 0.0,
        queue_s: 0.0,
        exec_s: 7.0,
        e2e_s: 7.0,
        end: 7.0,
        slo_s: 5.0,
        verdict: Verdict::Completed,
        avg_vcpus_used: 15.5,
        peak_vcpus_used: 16.0,
        mem_used_gb: 2.0,
    };
    bench::run_batched("vcpu cost vector", 100, 100, 100, || {
        bench::keep(cost::vcpu_costs(&rec, SlackPolicy::absolute_default()));
    });
    bench::run_batched("mem cost vector", 100, 100, 100, || {
        bench::keep(cost::mem_costs(&rec));
    });
    println!("  (paper fig14: featurization 0.13-35 ms modeled; see experiment fig14)");
}
