//! Sweep-harness determinism contract (DESIGN.md §4):
//! * the same (base seed, grid) must produce **byte-identical** per-seed
//!   metrics and cross-seed aggregates at `--jobs 1` and `--jobs 8`;
//! * distinct derived seeds must produce distinct `InvocationRecord`
//!   streams (replication actually samples different stochastic worlds).

use shabari::experiments::common::{make_policy, run_cell, sim_config, trace_seed, Ctx};
use shabari::experiments::sweep::{self, Cell};
use shabari::metrics::RunMetrics;
use shabari::simulator::engine::simulate;

fn quick_ctx() -> Ctx {
    Ctx { duration_s: 60.0, ..Default::default() }
}

/// Every scalar we assert byte-equality on, as raw bits.
fn metric_bits(m: &RunMetrics) -> Vec<u64> {
    vec![
        m.invocations as u64,
        m.slo_violation_pct.to_bits(),
        m.wasted_vcpus.p50.to_bits(),
        m.wasted_vcpus.p95.to_bits(),
        m.wasted_mem_gb.p50.to_bits(),
        m.vcpu_utilization.p50.to_bits(),
        m.cold_start_pct.to_bits(),
        m.mean_e2e_s.to_bits(),
        m.throughput.to_bits(),
        m.containers_created,
    ]
}

#[test]
fn aggregates_byte_identical_across_job_counts() {
    let ctx = quick_ctx();
    let cells = vec![
        Cell::new("static-medium", 2.0),
        Cell::new("shabari", 2.0),
        Cell::new("cypress", 3.0),
    ];
    let sweep_with = |jobs: usize| {
        sweep::run_cells(&cells, ctx.seed, 3, jobs, |cell, seed| {
            run_cell(&cell.policy, &ctx, cell.rps, seed)
        })
        .unwrap()
    };
    let sequential = sweep_with(1);
    let parallel = sweep_with(8);
    assert_eq!(sequential.len(), parallel.len());
    for (a, b) in sequential.iter().zip(&parallel) {
        assert_eq!(a.per_seed.len(), 3);
        // per-seed metrics identical bit-for-bit
        for (ma, mb) in a.per_seed.iter().zip(&b.per_seed) {
            assert_eq!(
                metric_bits(ma),
                metric_bits(mb),
                "cell {} diverged between --jobs 1 and --jobs 8",
                a.cell.id()
            );
        }
        // cross-seed aggregates identical bit-for-bit (mean metrics,
        // seed stats incl. the fixed-seed bootstrap CI)
        assert_eq!(metric_bits(&a.mean_metrics()), metric_bits(&b.mean_metrics()));
        let sa = a.stat(|m| m.slo_violation_pct);
        let sb = b.stat(|m| m.slo_violation_pct);
        assert_eq!(sa.mean.to_bits(), sb.mean.to_bits());
        assert_eq!(sa.p50.to_bits(), sb.p50.to_bits());
        assert_eq!(sa.p99.to_bits(), sb.p99.to_bits());
        assert_eq!(sa.ci95.0.to_bits(), sb.ci95.0.to_bits());
        assert_eq!(sa.ci95.1.to_bits(), sb.ci95.1.to_bits());
    }
}

#[test]
fn faulty_cells_byte_identical_across_job_counts() {
    // The fault axis rides the same determinism contract as everything
    // else (ISSUE 6): a cell running crash/straggler/hetero injection
    // must produce bit-identical per-seed metrics — including the fault
    // counters and Failed percentages — at any `--jobs`.
    use shabari::simulator::faults;
    let base = quick_ctx();
    let cells = vec![Cell::new("shabari", 3.0), Cell::new("static-medium", 3.0)];
    let sweep_with = |jobs: usize, profile: &str| {
        let ctx = Ctx { faults: faults::parse(profile).unwrap(), ..base.clone() };
        sweep::run_cells(&cells, ctx.seed, 2, jobs, move |cell, seed| {
            run_cell(&cell.policy, &ctx, cell.rps, seed)
        })
        .unwrap()
    };
    for profile in ["chaos:15", "stragglers:0.4"] {
        let sequential = sweep_with(1, profile);
        let parallel = sweep_with(8, profile);
        for (a, b) in sequential.iter().zip(&parallel) {
            for (ma, mb) in a.per_seed.iter().zip(&b.per_seed) {
                assert_eq!(
                    metric_bits(ma),
                    metric_bits(mb),
                    "faulty cell {} ({profile}) diverged across --jobs",
                    a.cell.id()
                );
                assert_eq!(ma.worker_crashes, mb.worker_crashes);
                assert_eq!(ma.requeued_on_crash, mb.requeued_on_crash);
                assert_eq!(ma.failed_pct.to_bits(), mb.failed_pct.to_bits());
                assert_eq!(ma.straggler_slowdown.to_bits(), mb.straggler_slowdown.to_bits());
            }
        }
    }
}

#[test]
fn rerunning_a_sweep_is_deterministic() {
    let ctx = quick_ctx();
    let cells = vec![Cell::new("static-large", 2.0)];
    let run = || {
        sweep::run_cells(&cells, ctx.seed, 2, 4, |cell, seed| {
            run_cell(&cell.policy, &ctx, cell.rps, seed)
        })
        .unwrap()[0]
            .per_seed
            .iter()
            .map(metric_bits)
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn distinct_seeds_produce_distinct_record_streams() {
    let base = quick_ctx();
    let cell = Cell::new("static-medium", 2.0);
    let records_for = |replicate: usize| {
        let seed = sweep::cell_seed(base.seed, &cell, replicate);
        let ctx = base.with_seed(seed);
        let workload = ctx.workload();
        let mut policy = make_policy(&cell.policy, &ctx, &workload).unwrap();
        let trace = workload.trace(cell.rps, ctx.duration_s, trace_seed(&ctx, cell.rps));
        let res = simulate(sim_config(&ctx), &mut policy, trace);
        let mut recs: Vec<(u64, u64, u64)> = res
            .records
            .iter()
            .map(|r| (r.id, r.exec_s.to_bits(), r.e2e_s.to_bits()))
            .collect();
        recs.sort();
        recs
    };
    let a = records_for(0);
    let b = records_for(1);
    assert!(!a.is_empty() && !b.is_empty());
    assert_ne!(a, b, "different replicates must sample different worlds");
    // and the same replicate reproduces its stream exactly
    assert_eq!(a, records_for(0));
}

#[test]
fn per_seed_replicates_differ_within_a_cell() {
    // The harness end-to-end: one cell, three seeds; the three metric sets
    // must not all coincide (the workload/trace/policy are re-seeded).
    let ctx = quick_ctx();
    let cells = vec![Cell::new("static-medium", 2.0)];
    let outcomes = sweep::run_cells(&cells, ctx.seed, 3, 2, |cell, seed| {
        run_cell(&cell.policy, &ctx, cell.rps, seed)
    })
    .unwrap();
    let bits: Vec<Vec<u64>> = outcomes[0].per_seed.iter().map(metric_bits).collect();
    assert!(
        bits[0] != bits[1] || bits[1] != bits[2],
        "replicates collapsed to one stochastic world"
    );
}
