//! Scenario-subsystem contract (DESIGN.md §Scenarios):
//! * `azure-synthetic` behind the `Scenario` trait reproduces the direct
//!   `azure::arrival_times` + uniform-sampling recipe **byte-for-byte**
//!   (arrivals, function picks, input picks, SLOs) — the trait refactor
//!   introduces zero drift, so replicate 0 of every sweep replays exactly
//!   what a pre-trait single run of this build would produce;
//! * every registered scenario produces sorted, bounded, seed-deterministic
//!   arrivals at (approximately) the requested rate;
//! * `trace-file` round-trips the checked-in sample CSV from disk;
//! * the Zipf mix matches the requested skew;
//! * the policy × scenario robustness grid is byte-identical across
//!   `--jobs` values.

use shabari::experiments::common::Ctx;
use shabari::experiments::scenarios::run_matrix;
use shabari::functions::catalog::CATALOG;
use shabari::metrics::RunMetrics;
use shabari::util::prop;
use shabari::util::rng::Rng;
use shabari::workload::scenario::{self, shapes::ZipfSkew, trace_file::TraceFile, Scenario};
use shabari::workload::{azure, Workload, SALT_TRACE};

/// The pre-scenario trace recipe, inlined: this is the code shape
/// `Workload::trace_over` had before the `Scenario` trait existed (same
/// salt, `azure::arrival_times`, then uniform choose/below per arrival).
/// The trait-routed path must reproduce it exactly — any extra RNG draw,
/// reordering, or changed salt in the scenario plumbing shows up here.
fn legacy_trace(
    w: &Workload,
    funcs: &[usize],
    rps: f64,
    duration_s: f64,
    seed: u64,
) -> Vec<(f64, usize, usize)> {
    // lint:allow(D010): the byte-pin deliberately mirrors the production
    // SALT_TRACE fork to prove the trait refactor replays it exactly
    let mut rng = Rng::new(seed ^ SALT_TRACE);
    let starts = azure::arrival_times(rps, duration_s, &mut rng);
    starts
        .into_iter()
        .map(|at| {
            let func = *rng.choose(funcs);
            let input_idx = rng.below(w.pools[func].len());
            (at, func, input_idx)
        })
        .collect()
}

#[test]
fn azure_synthetic_reproduces_the_legacy_trace_byte_for_byte() {
    let w = Workload::build(1, 1.4);
    let funcs: Vec<usize> = (0..CATALOG.len()).collect();
    for (rps, seed) in [(2.0, 7u64), (4.0, 42), (6.0, 1234)] {
        let legacy = legacy_trace(&w, &funcs, rps, 300.0, seed);
        let trace = w.trace(rps, 300.0, seed);
        assert_eq!(trace.len(), legacy.len(), "rps {rps} seed {seed}: length");
        for (req, (at, func, input_idx)) in trace.iter().zip(&legacy) {
            assert_eq!(req.arrival.to_bits(), at.to_bits(), "arrival bits");
            assert_eq!(req.func, *func, "function pick");
            let pool_input = &w.pools[*func][*input_idx];
            assert_eq!(req.input.id, pool_input.id, "input pick (id)");
            assert_eq!(req.input.kind, pool_input.kind, "input pick (kind)");
            assert_eq!(
                req.input.size_bytes.to_bits(),
                pool_input.size_bytes.to_bits(),
                "input pick (size)"
            );
            assert_eq!(
                req.slo_s.to_bits(),
                w.slos[*func][*input_idx].to_bits(),
                "slo bits"
            );
        }
        // the named scenario is the same object as the default path
        let via_name = scenario::by_name("azure-synthetic").unwrap();
        let named = w.trace_with(via_name.as_ref(), rps, 300.0, seed);
        assert_eq!(named.len(), trace.len());
        assert!(named
            .iter()
            .zip(&trace)
            .all(|(a, b)| a.arrival.to_bits() == b.arrival.to_bits() && a.func == b.func));
    }
}

#[test]
fn every_scenario_satisfies_the_arrival_properties() {
    // property-check across seeds: sorted, bounded, deterministic, and
    // (flash-crowd excepted, which adds burst load by design) near-target
    for name in scenario::SCENARIOS {
        let s = scenario::by_name(name).unwrap();
        prop::check(0x5CE0 ^ shabari::util::rng::fnv1a(name.as_bytes()), 10, |rng| {
            let seed = rng.next_u64();
            let a = s.arrival_times(4.0, 600.0, &mut Rng::new(seed));
            let b = s.arrival_times(4.0, 600.0, &mut Rng::new(seed));
            assert_eq!(a, b, "{name}: deterministic per seed");
            assert!(!a.is_empty(), "{name}: nonempty");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{name}: sorted");
            assert!(a.iter().all(|t| (0.0..=600.0).contains(t)), "{name}: bounded");
            let rate = a.len() as f64 / 600.0;
            if *name == "flash-crowd" {
                assert!(rate >= 4.0, "{name}: burst adds load, rate {rate}");
                assert!(rate <= 4.0 * 4.0, "{name}: bounded by k x base, rate {rate}");
            } else {
                assert!((rate - 4.0).abs() < 0.8, "{name}: rate {rate}");
            }
        });
    }
}

#[test]
fn partial_minute_windows_deliver_the_full_requested_rate() {
    // 90 s and 330 s end mid-minute. Before the PR 10 `minute_starts`
    // fix, the whole final partial minute's mass was silently dropped
    // (a 33% deficit at 90 s, 9% at 330 s); after the clamp-and-rescale
    // fix, every scenario delivers the requested rate on any window.
    for name in scenario::SCENARIOS {
        let s = scenario::by_name(name).unwrap();
        for duration in [90.0, 330.0] {
            let (seeds, rps) = (40u64, 6.0);
            let mut total = 0usize;
            for seed in 0..seeds {
                let a = s.arrival_times(rps, duration, &mut Rng::new(0xD0_0000 + seed));
                assert!(
                    a.iter().all(|t| (0.0..=duration).contains(t)),
                    "{name}@{duration}s: arrival outside the window"
                );
                total += a.len();
            }
            let rate = total as f64 / (seeds as f64 * duration);
            if *name == "flash-crowd" {
                // the burst adds load on top of the base rate by design
                assert!(
                    rate >= rps * 0.92 && rate <= rps * 4.0,
                    "{name}@{duration}s: rate {rate:.2} vs base {rps}"
                );
            } else {
                assert!(
                    (rate - rps).abs() < 0.08 * rps,
                    "{name}@{duration}s: rate {rate:.2} vs requested {rps} \
                     (partial-minute mass lost?)"
                );
            }
        }
    }
}

#[test]
fn trace_file_round_trips_the_sample_csv() {
    // integration tests run with cwd = the crate root (rust/)
    let from_disk = TraceFile::from_path("data/azure_sample.csv").unwrap();
    let embedded = TraceFile::sample().unwrap();
    assert_eq!(from_disk.per_minute(), embedded.per_minute(), "disk vs embedded profile");
    // identical profiles generate identical arrivals
    let a = from_disk.arrival_times(4.0, 600.0, &mut Rng::new(3));
    let b = embedded.arrival_times(4.0, 600.0, &mut Rng::new(3));
    assert_eq!(a, b);
    // and the registry's path form loads the same file
    let via_registry = scenario::by_name("trace-file:data/azure_sample.csv").unwrap();
    let c = via_registry.arrival_times(4.0, 600.0, &mut Rng::new(3));
    assert_eq!(a, c);
}

#[test]
fn trace_file_missing_path_errors_with_the_path_no_panic() {
    // The CLI fail-fast check routes through `scenario::by_name`, so a
    // typo'd path must come back as a clean error citing the path — not
    // a panic, and not a silent fall-back to the embedded sample.
    let err = scenario::by_name("trace-file:data/no_such_trace_anywhere.csv").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no_such_trace_anywhere.csv"), "error must cite the path: {msg}");
    assert!(msg.contains("reading trace file"), "error must say what failed: {msg}");
}

#[test]
fn trace_file_malformed_rows_error_with_row_context_no_panic() {
    // Unique filenames per case: the parsed-profile cache memoizes by
    // path for the life of the process, so reusing a name across cases
    // (or with another test) could serve a stale parse.
    let dir = std::env::temp_dir();
    let write = |name: &str, text: &str| {
        let path = dir.join(format!("shabari_negpath_{}_{name}", std::process::id()));
        std::fs::write(&path, text).unwrap();
        format!("{}", path.display())
    };

    // non-numeric count on file line 3: the error must carry the real
    // line number and the offending field through the registry wrapper
    let p = write("bad_count.csv", "HashOwner,Trigger,1,2\nabc,http,1,2\ndef,http,3,oops\n");
    let msg = format!("{:#}", scenario::by_name(&format!("trace-file:{p}")).unwrap_err());
    assert!(msg.contains("parsing trace file"), "{msg}");
    assert!(msg.contains("line 3"), "row context lost: {msg}");
    assert!(msg.contains("oops"), "offending field lost: {msg}");

    // a truncated row (too few columns) is a row error, not an index panic
    let p = write("short_row.csv", "HashOwner,Trigger,1,2\nabc,http\n");
    let msg = format!("{:#}", scenario::by_name(&format!("trace-file:{p}")).unwrap_err());
    assert!(msg.contains("line 2"), "{msg}");

    // structurally hopeless files: empty, no minute columns, zero mass
    for (name, text) in [
        ("empty.csv", ""),
        ("no_minutes.csv", "HashOwner,HashApp,Trigger\nabc,def,http\n"),
        ("zero_mass.csv", "HashOwner,Trigger,1,2\nabc,http,0,0\n"),
    ] {
        let p = write(name, text);
        assert!(
            scenario::by_name(&format!("trace-file:{p}")).is_err(),
            "{name} must be rejected"
        );
    }
}

#[test]
fn zipf_mix_matches_the_requested_skew() {
    let w = Workload::build(1, 1.4);
    let z = ZipfSkew::new(1.1);
    let trace = w.trace_with(&z, 20.0, 600.0, 9);
    assert!(trace.len() > 10_000, "need mass for a tight histogram");
    let mut counts = vec![0usize; CATALOG.len()];
    for r in &trace {
        counts[r.func] += 1;
    }
    let weights = z.weights(CATALOG.len());
    let total_w: f64 = weights.iter().sum();
    let n = trace.len() as f64;
    // every rank's empirical share within 25% relative of its Zipf mass
    // (ranks are catalog order; tail ranks carry ~2% each at s = 1.1)
    for (i, (&c, &wgt)) in counts.iter().zip(&weights).enumerate() {
        let got = c as f64 / n;
        let expect = wgt / total_w;
        assert!(
            (got - expect).abs() < 0.25 * expect,
            "rank {i}: got {got:.4}, expected {expect:.4} ({counts:?})"
        );
    }
    // head function dominates the tail by the theoretical factor
    assert!(counts[0] > 5 * counts[CATALOG.len() - 1], "{counts:?}");
}

/// Every scalar we assert byte-equality on, as raw bits.
fn metric_bits(m: &RunMetrics) -> Vec<u64> {
    vec![
        m.invocations as u64,
        m.slo_violation_pct.to_bits(),
        m.wasted_vcpus.p50.to_bits(),
        m.wasted_mem_gb.p50.to_bits(),
        m.cold_start_pct.to_bits(),
        m.mean_e2e_s.to_bits(),
        m.throughput.to_bits(),
    ]
}

#[test]
fn scenario_grid_byte_identical_across_job_counts() {
    let ctx = Ctx { duration_s: 60.0, ..Default::default() };
    let matrix_with = |jobs: usize| {
        let ctx = Ctx { jobs, seeds: 2, ..ctx.clone() };
        run_matrix(&ctx, 2.0).unwrap()
    };
    let sequential = matrix_with(1);
    let parallel = matrix_with(8);
    assert_eq!(sequential.len(), parallel.len());
    for (a, b) in sequential.iter().zip(&parallel) {
        assert_eq!(a.cell.id(), b.cell.id());
        for (ma, mb) in a.per_seed.iter().zip(&b.per_seed) {
            assert_eq!(
                metric_bits(ma),
                metric_bits(mb),
                "cell {} diverged between --jobs 1 and --jobs 8",
                a.cell.id()
            );
        }
        let sa = a.stat(|m| m.slo_violation_pct);
        let sb = b.stat(|m| m.slo_violation_pct);
        assert_eq!(sa.mean.to_bits(), sb.mean.to_bits());
        assert_eq!(sa.ci95.0.to_bits(), sb.ci95.0.to_bits());
        assert_eq!(sa.ci95.1.to_bits(), sb.ci95.1.to_bits());
    }
}

#[test]
fn scenarios_separate_policies_from_shapes() {
    // the same seed under two scenarios must differ, and the same
    // (seed, scenario) pair must reproduce — end-to-end through Ctx
    let base = Ctx { duration_s: 120.0, ..Default::default() };
    let run = |scenario: &str| {
        let ctx = base.with_scenario(scenario);
        shabari::experiments::common::run_cell("static-medium", &ctx, 3.0, 77).unwrap()
    };
    let diurnal = run("diurnal");
    let zipf = run("zipf-skew");
    assert_ne!(
        metric_bits(&diurnal),
        metric_bits(&zipf),
        "different shapes must sample different worlds"
    );
    assert_eq!(metric_bits(&diurnal), metric_bits(&run("diurnal")), "reproducible");
}
