//! Engine-core determinism under tie-heavy workloads (ISSUE 3 / DESIGN
//! §4): many simultaneous invocations of one function on one worker
//! produce a maximally tie-rich event schedule — every same-timestamp
//! completion batch, warm-pool race, and processor-sharing recompute
//! lands on the deterministic indexed structures. Two runs must agree
//! byte-for-byte on the *ordered* record stream (completion order is
//! `policy.on_complete` feedback order) **and** on the learner's model
//! state (SGD is order-sensitive, so a hash-ordered feedback stream
//! would silently diverge the models even when aggregate metrics agree).

use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::featurizer::featurize;
use shabari::functions::catalog::{index_of, CATALOG};
use shabari::functions::inputs;
use shabari::simulator::engine::simulate;
use shabari::simulator::{Request, SimConfig, Verdict};
use shabari::util::rng::Rng;

/// 3 waves x 20 simultaneous qr invocations on a single worker.
fn tie_heavy_requests() -> (usize, Vec<Request>) {
    let fi = index_of("qr").unwrap();
    let mut rng = Rng::new(11);
    let pool = inputs::pool(&CATALOG[fi], &mut rng);
    let mut reqs = Vec::new();
    for wave in 0..3u64 {
        for i in 0..20u64 {
            let id = wave * 20 + i + 1;
            reqs.push(Request {
                id,
                func: fi,
                input: pool[(id as usize) % pool.len()].clone(),
                arrival: wave as f64 * 15.0,
                slo_s: 1.0,
            });
        }
    }
    (fi, reqs)
}

/// One full run: ordered record fingerprint + learner model state.
fn run_once() -> (Vec<(u64, u64, u64, u32, u32, bool)>, Vec<u32>) {
    let (fi, reqs) = tie_heavy_requests();
    let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
    let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(7)));
    let cfg = SimConfig { workers: 1, ..SimConfig::default() };
    let res = simulate(cfg, &mut policy, reqs);

    // Completion order, not arrival order: this is the exact sequence the
    // learner saw feedback in.
    let stream: Vec<(u64, u64, u64, u32, u32, bool)> = res
        .records
        .iter()
        .map(|r| {
            (
                r.id,
                r.exec_s.to_bits(),
                r.e2e_s.to_bits(),
                r.vcpus,
                r.mem_mb,
                r.verdict == Verdict::Completed,
            )
        })
        .collect();

    // Model-state fingerprint: post-run vCPU scores on a fixed probe.
    let probe = featurize(&res.records[0].input).vector.with_slo(1.0);
    let scores = policy.allocator.vcpu_scores_for(fi, &probe);
    let score_bits: Vec<u32> = scores.iter().map(|s| s.to_bits()).collect();

    // The refactor's index bookkeeping must also survive a tie-heavy run.
    res.cluster.assert_warm_consistent();
    (stream, score_bits)
}

#[test]
fn tie_heavy_run_is_byte_deterministic_including_learner_state() {
    let (stream_a, scores_a) = run_once();
    let (stream_b, scores_b) = run_once();
    assert_eq!(stream_a.len(), 60, "all invocations must complete");
    assert_eq!(
        stream_a, stream_b,
        "ordered record streams diverged across identical runs"
    );
    assert_eq!(
        scores_a, scores_b,
        "learner model state diverged: on_complete feedback order is not deterministic"
    );
}

/// Full-stream fingerprint of the tie-heavy workload under an arbitrary
/// config: ordered records (id, timing bits, sizing, verdict) + learner
/// model state + fault counters.
fn fingerprint(cfg: SimConfig) -> (Vec<(u64, u64, u64, u32, u32, u8)>, Vec<u32>, u64, u64) {
    let (fi, reqs) = tie_heavy_requests();
    let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
    let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(7)));
    let res = simulate(cfg, &mut policy, reqs);
    let stream: Vec<(u64, u64, u64, u32, u32, u8)> = res
        .records
        .iter()
        .map(|r| {
            let v = match r.verdict {
                Verdict::Completed => 0u8,
                Verdict::OomKilled => 1,
                Verdict::TimedOut => 2,
                Verdict::Failed => 3,
            };
            (r.id, r.exec_s.to_bits(), r.e2e_s.to_bits(), r.vcpus, r.mem_mb, v)
        })
        .collect();
    let probe = featurize(&res.records[0].input).vector.with_slo(1.0);
    let scores = policy.allocator.vcpu_scores_for(fi, &probe);
    let score_bits: Vec<u32> = scores.iter().map(|s| s.to_bits()).collect();
    res.cluster.check_invariants();
    (stream, score_bits, res.worker_crashes, res.requeued_on_crash)
}

#[test]
fn faults_none_is_byte_identical_to_the_default_config() {
    // The fault axis at `none` must be a true no-op (ISSUE 6): a config
    // that never mentions faults and one that explicitly parses
    // `--faults none` produce byte-identical record streams and learner
    // state — zero extra RNG draws, zero extra events, zero crashes.
    let plain = SimConfig { workers: 1, ..SimConfig::default() };
    let mut parsed = SimConfig { workers: 1, ..SimConfig::default() };
    shabari::simulator::faults::parse("none").unwrap().apply(&mut parsed);
    let a = fingerprint(plain);
    let b = fingerprint(parsed);
    assert_eq!(a.0.len(), 60, "all invocations must complete");
    assert_eq!(a, b, "--faults none perturbed the default byte stream");
    assert_eq!(a.2, 0, "no crashes under faults:none");
    assert!(a.0.iter().all(|r| r.5 != 3), "no Failed records under faults:none");
}

#[test]
fn scaler_none_is_byte_identical_to_the_default_config() {
    // The scaler axis at `none` must be a true no-op (ISSUE 10): a config
    // that never mentions a scaler and one that explicitly parses
    // `--scaler none` produce byte-identical record streams and learner
    // state — no scaler state is built, no tick event is seeded, and the
    // `SALT_SCALER` stream is never forked.
    let plain = SimConfig { workers: 1, ..SimConfig::default() };
    let mut parsed = SimConfig { workers: 1, ..SimConfig::default() };
    shabari::simulator::scaler::parse("none").unwrap().apply(&mut parsed);
    let a = fingerprint(plain);
    let b = fingerprint(parsed);
    assert_eq!(a.0.len(), 60, "all invocations must complete");
    assert_eq!(a, b, "--scaler none perturbed the default byte stream");
}

#[test]
fn fifer_scaled_runs_are_byte_deterministic() {
    // Scaling decisions ride the ordinary event heap and a dedicated RNG
    // fork, so the same scaled config twice must agree byte-for-byte —
    // including the cluster invariants (checked inside `fingerprint`)
    // after any extension workers join and drain. The tie-heavy
    // single-worker wave load saturates the pool, giving the queue-depth
    // signal real material to react to.
    let cfg = || {
        let mut c = SimConfig { workers: 1, ..SimConfig::default() };
        shabari::simulator::scaler::parse("fifer").unwrap().apply(&mut c);
        c
    };
    let a = fingerprint(cfg());
    let b = fingerprint(cfg());
    assert_eq!(a.0.len(), 60, "all invocations must complete under scaling");
    assert_eq!(a, b, "fifer-scaled runs diverged across identical configs");
}

#[test]
fn tracing_leaves_the_record_stream_byte_identical() {
    // The trace sink must be pure observation (the observability PR's
    // zero-cost-when-on guarantee for *simulation state*): a traced run
    // draws zero extra RNG values and schedules zero extra events, so
    // records, learner state, and fault counters are byte-identical to
    // the untraced run — the trace rides entirely on the side.
    let plain = SimConfig { workers: 1, ..SimConfig::default() };
    let traced = SimConfig {
        workers: 1,
        trace: Some(shabari::simulator::trace::TraceConfig { sample_interval_s: 5.0 }),
        ..SimConfig::default()
    };
    let a = fingerprint(plain);
    let b = fingerprint(traced);
    assert_eq!(a.0.len(), 60, "all invocations must complete");
    assert_eq!(a, b, "enabling --trace perturbed the byte stream");
}

#[test]
fn faulty_runs_are_byte_deterministic() {
    // Crash/restart cycles, stragglers, and heterogeneous workers are all
    // seed-derived: the same config twice (including any Failed verdicts
    // and requeue counters) must agree byte-for-byte, and the per-worker
    // invariants must hold after teardown/restart churn.
    let mut cfg = SimConfig { workers: 2, ..SimConfig::default() };
    shabari::simulator::faults::parse("chaos:20").unwrap().apply(&mut cfg);
    let a = fingerprint(cfg.clone());
    let b = fingerprint(cfg);
    assert_eq!(a.0.len(), 60, "every arrival must still terminate exactly once");
    assert_eq!(a, b, "faulty record streams diverged across identical runs");
    assert!(a.2 > 0, "chaos profile must schedule at least one crash");
}

#[test]
fn completion_feedback_arrives_in_invocation_id_order_within_a_batch() {
    // All 20 wave-0 invocations share arrival, input sizes, and one
    // worker; batches that complete at one timestamp must surface in
    // ascending invocation id. Verify orderedness pairwise: whenever two
    // adjacent records share a completion timestamp, ids must ascend.
    let (_, reqs) = tie_heavy_requests();
    let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
    let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(7)));
    let cfg = SimConfig { workers: 1, ..SimConfig::default() };
    let res = simulate(cfg, &mut policy, reqs);
    for pair in res.records.windows(2) {
        if pair[0].end.to_bits() == pair[1].end.to_bits() {
            assert!(
                pair[0].id < pair[1].id,
                "same-timestamp completions out of id order: {} then {}",
                pair[0].id,
                pair[1].id
            );
        }
    }
}
