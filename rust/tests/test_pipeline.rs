//! Full-pipeline integration: trace generation → Shabari coordinator →
//! DES cluster → metrics, including the XLA production path when
//! artifacts are present.

use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::experiments::common::{make_policy, run_one, sim_config, Ctx};
use shabari::learner::xla::Backend;
use shabari::metrics::from_result;
use shabari::simulator::engine::simulate;
use shabari::simulator::SimConfig;
use shabari::workload::Workload;

fn artifacts_present() -> bool {
    // The XLA paths need both the AOT artifacts on disk and a build with
    // the `xla` feature; otherwise those tests skip (the native mirror is
    // exercised everywhere else).
    cfg!(feature = "xla")
        && std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
}

#[test]
fn full_trace_all_policies_complete() {
    let ctx = Ctx { duration_s: 120.0, ..Default::default() };
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    for name in shabari::experiments::common::POLICIES {
        let (res, m) = run_one(name, &ctx, &w, 3.0, &cfg).unwrap();
        assert_eq!(res.records.len(), m.invocations, "{name}");
        assert!(m.invocations > 100, "{name}: {} invocations", m.invocations);
        // every invocation reaches a terminal state and is accounted
        assert!(m.slo_violation_pct <= 100.0);
    }
}

#[test]
fn shabari_beats_statics_on_waste_everywhere() {
    let ctx = Ctx { duration_s: 300.0, ..Default::default() };
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let (_, shabari) = run_one("shabari", &ctx, &w, 4.0, &cfg).unwrap();
    let (_, medium) = run_one("static-medium", &ctx, &w, 4.0, &cfg).unwrap();
    assert!(shabari.wasted_vcpus.p50 < medium.wasted_vcpus.p50);
    assert!(shabari.wasted_mem_gb.p50 < medium.wasted_mem_gb.p50);
    assert!(shabari.vcpu_utilization.p50 > medium.vcpu_utilization.p50);
    assert!(shabari.slo_violation_pct < medium.slo_violation_pct);
}

#[test]
fn xla_production_path_runs_the_full_pipeline() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut cfg = AllocatorConfig::xla(artifacts.to_str().unwrap());
    cfg.learner_backend = Backend::Xla;
    let allocator = ResourceAllocator::new(cfg).unwrap();
    let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(7)));
    let w = Workload::build(42, 1.4);
    let trace = w.trace(2.0, 90.0, 13);
    let n = trace.len();
    let res = simulate(SimConfig::small(), &mut policy, trace);
    assert_eq!(res.records.len(), n);
    let m = from_result("shabari-xla", &res);
    assert!(m.slo_violation_pct < 50.0, "XLA path must behave sanely");
}

#[test]
fn xla_and_native_backends_agree_on_decisions() {
    if !artifacts_present() {
        return;
    }
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let w = Workload::build(42, 1.4);
    let trace = w.trace(2.0, 60.0, 5);

    let run = |backend: Backend| {
        let mut cfg = AllocatorConfig::default();
        cfg.learner_backend = backend;
        cfg.artifacts_dir = artifacts.to_str().unwrap().to_string();
        let allocator = ResourceAllocator::new(cfg).unwrap();
        let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(9)));
        let res = simulate(SimConfig::small(), &mut policy, trace.clone());
        let mut rs = res.records;
        rs.sort_by_key(|r| r.id);
        rs.iter().map(|r| (r.requested_vcpus, r.requested_mem_mb)).collect::<Vec<_>>()
    };
    let native = run(Backend::Native);
    let xla = run(Backend::Xla);
    // identical math modulo f32 round-off: allocations may differ on an
    // argmin tie, but the overwhelming majority must agree exactly
    let agree = native.iter().zip(&xla).filter(|(a, b)| a == b).count();
    assert!(
        agree * 100 >= native.len() * 95,
        "backends agree on {}/{} decisions",
        agree,
        native.len()
    );
}

#[test]
fn deterministic_end_to_end() {
    let ctx = Ctx { duration_s: 120.0, ..Default::default() };
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let run = || {
        let mut p = make_policy("shabari", &ctx, &w).unwrap();
        let trace = w.trace(3.0, ctx.duration_s, 21);
        let res = simulate(cfg.clone(), &mut p, trace);
        let mut v: Vec<(u64, u32, u64)> = res
            .records
            .iter()
            .map(|r| (r.id, r.requested_vcpus, (r.exec_s * 1e6) as u64))
            .collect();
        v.sort();
        v
    };
    assert_eq!(run(), run());
}

#[test]
fn overheads_propagate_to_e2e_latency() {
    let ctx = Ctx { duration_s: 120.0, ..Default::default() };
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let (res, _) = run_one("shabari", &ctx, &w, 2.0, &cfg).unwrap();
    for r in &res.records {
        assert!(
            r.e2e_s + 1e-9 >= r.exec_s + r.cold_start_s + r.overhead_s,
            "e2e {} must include exec {} + cold {} + overhead {}",
            r.e2e_s,
            r.exec_s,
            r.cold_start_s,
            r.overhead_s
        );
        assert!(r.overhead_s > 0.0, "decision overhead is never free");
    }
}
