//! Warm-pool test battery for the pluggable keep-alive subsystem
//! (ISSUE 5 / DESIGN.md §KeepAlive): the `fixed:600` spec must reproduce
//! the default config's record streams byte-for-byte (the refactor adds
//! no RNG draws and no event reordering in fixed mode), every policy's
//! streams must be deterministic across runs and `--jobs`, evictions
//! must respect their policy deadlines (`Expired` fires exactly at the
//! deadline, `Pressure` at or before it, never touching running work),
//! and a parked admission bind must be admitted *via* pressure eviction
//! with `queue_s > 0`.

use shabari::baselines::StaticPolicy;
use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::experiments::common::{run_cell, Ctx};
use shabari::experiments::sweep::{self, Cell};
use shabari::featurizer::{InputKind, InputSpec};
use shabari::functions::catalog::index_of;
use shabari::simulator::engine::{simulate, EvictReason, SimResult};
use shabari::simulator::keepalive::{self, KeepAliveMode};
use shabari::simulator::worker::Cluster;
use shabari::simulator::{
    ContainerChoice, Decision, Policy, Request, SimConfig, SimTime, Verdict,
};
use shabari::util::prop;
use shabari::util::rng::Rng;

fn qr_request(id: u64, at: f64) -> Request {
    let mut input = InputSpec::new(InputKind::Payload);
    input.length = 100.0;
    input.size_bytes = 100.0;
    Request { id, func: index_of("qr").unwrap(), input, arrival: at, slo_s: 1.0 }
}

fn compress_request(id: u64, at: f64, mb: f64) -> Request {
    let mut input = InputSpec::new(InputKind::File);
    input.id = id | 1;
    input.size_bytes = mb * 1024.0 * 1024.0;
    Request { id, func: index_of("compress").unwrap(), input, arrival: at, slo_s: 60.0 }
}

/// Fixed-size policy with optional exact-size warm reuse (the engine's
/// own test policy, re-declared: it is private to `engine.rs`).
struct SizedPolicy {
    vcpus: u32,
    mem_mb: u32,
    next: usize,
    reuse_warm: bool,
}

impl Policy for SizedPolicy {
    fn name(&self) -> String {
        "sized".into()
    }

    fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
        if self.reuse_warm {
            if let Some((w, cid)) = cluster.find_warm_exact(req.func, self.vcpus, self.mem_mb) {
                return Decision {
                    worker: w,
                    vcpus: self.vcpus,
                    mem_mb: self.mem_mb,
                    container: ContainerChoice::Warm(cid),
                    background: None,
                    overhead_s: 0.0,
                };
            }
        }
        let w = self.next % cluster.len();
        self.next += 1;
        Decision {
            worker: w,
            vcpus: self.vcpus,
            mem_mb: self.mem_mb,
            container: ContainerChoice::Cold,
            background: None,
            overhead_s: 0.0,
        }
    }
}

/// Ordered byte-level fingerprint of a run: records + eviction log +
/// keep-alive counters.
type Fingerprint = (Vec<(u64, u64, u64, u64, u32, bool)>, Vec<(u64, u64, u64, u8)>, [u64; 5]);

fn fingerprint(res: &SimResult) -> Fingerprint {
    let records = res
        .records
        .iter()
        .map(|r| {
            (
                r.id,
                r.queue_s.to_bits(),
                r.exec_s.to_bits(),
                r.e2e_s.to_bits(),
                r.vcpus,
                r.verdict == Verdict::Completed,
            )
        })
        .collect();
    let evictions = res
        .evictions
        .iter()
        .map(|e| {
            (
                e.container,
                e.at.to_bits(),
                e.deadline.to_bits(),
                (e.reason == EvictReason::Pressure) as u8,
            )
        })
        .collect();
    let counters = [
        res.containers_created,
        res.pressure_evictions,
        res.prewarm_launches,
        res.prewarm_hits,
        res.idle_container_s.to_bits(),
    ];
    (records, evictions, counters)
}

/// The full coordinator on an overloaded worker (queueing + learner
/// feedback + keep-alive all active) under a given config.
fn coordinator_run(cfg: SimConfig) -> SimResult {
    let reqs: Vec<Request> =
        (0..30).map(|i| compress_request(i + 1, (i / 10) as f64 * 5.0, 256.0)).collect();
    let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
    let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(3)));
    simulate(cfg, &mut policy, reqs)
}

#[test]
fn fixed_600_spec_reproduces_the_default_stream_byte_for_byte() {
    // The regression pin for the refactor: a config that never mentions
    // the keep-alive subsystem and one built from the CLI's
    // `--keepalive fixed:600` must produce identical streams — same
    // records, same eviction times, same counters, bit for bit. The
    // fixed path schedules the same events at the same sequence numbers
    // and draws nothing extra from the RNG, so any drift here is a bug
    // in the subsystem threading, not noise.
    let default_cfg = SimConfig { workers: 1, sched_vcpu_limit: 48.0, ..SimConfig::default() };
    let mut cli_cfg = default_cfg.clone();
    keepalive::parse("fixed:600").unwrap().apply(&mut cli_cfg);
    let a = coordinator_run(default_cfg);
    let b = coordinator_run(cli_cfg);
    assert_eq!(a.records.len(), 30);
    assert_eq!(
        fingerprint(&a),
        fingerprint(&b),
        "--keepalive fixed:600 diverged from the default stream"
    );
    assert_eq!(a.ready_miss, 0);
    assert_eq!(a.pressure_evictions, 0);
    assert_eq!(a.prewarm_launches, 0);
}

#[test]
fn every_policy_stream_is_byte_deterministic_across_runs() {
    for mode in [KeepAliveMode::Fixed, KeepAliveMode::Histogram, KeepAliveMode::Pressure] {
        let run = || {
            let cfg = SimConfig {
                workers: 1,
                sched_vcpu_limit: 48.0,
                keepalive: mode,
                ..SimConfig::default()
            };
            coordinator_run(cfg)
        };
        let a = run();
        let b = run();
        assert_eq!(a.records.len(), 30, "{mode:?}: every request records");
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{mode:?}: record/eviction streams diverged across identical runs"
        );
        a.cluster.assert_warm_consistent();
        a.cluster.assert_admission_consistent();
        assert_eq!(a.ready_miss, 0, "{mode:?}");
    }
}

#[test]
fn keepalive_cells_are_jobs_invariant_in_the_sweep_harness() {
    // `--keepalive` rides `Ctx` through the sweep harness: per-variant
    // aggregates must be byte-identical at --jobs 1 and --jobs 4.
    for variant in ["fixed:600", "histogram", "pressure"] {
        let ctx = Ctx {
            duration_s: 45.0,
            keepalive: keepalive::parse(variant).unwrap(),
            ..Default::default()
        };
        let cells = [Cell::new("static-large", 8.0)];
        let run = |jobs: usize| {
            let cctx = Ctx { jobs, seeds: 2, ..ctx.clone() };
            sweep::run_cells(&cells, cctx.seed, cctx.seeds, cctx.jobs, |cell, seed| {
                run_cell(&cell.policy, &cctx, cell.rps, seed)
            })
            .unwrap()
        };
        let seq = run(1);
        let par = run(4);
        for (a, b) in seq.iter().zip(&par) {
            let (ma, mb) = (a.mean_metrics(), b.mean_metrics());
            assert_eq!(ma.invocations, mb.invocations, "{variant}");
            assert_eq!(
                ma.idle_container_s.to_bits(),
                mb.idle_container_s.to_bits(),
                "{variant}: idle accounting diverged across --jobs"
            );
            assert_eq!(ma.evictions, mb.evictions, "{variant}");
            assert_eq!(ma.pressure_evictions, mb.pressure_evictions, "{variant}");
            assert_eq!(
                ma.slo_violation_pct.to_bits(),
                mb.slo_violation_pct.to_bits(),
                "{variant}"
            );
        }
    }
}

/// Audit a result's eviction log against the battery's deadline
/// properties.
fn audit_evictions(res: &SimResult, n_requests: usize, what: &str) {
    assert_eq!(
        res.records.len(),
        n_requests,
        "{what}: a lost record means an eviction tore down running work"
    );
    for e in &res.evictions {
        assert!(
            e.at >= e.idle_since - 1e-9,
            "{what}: eviction at {} precedes idle start {}",
            e.at,
            e.idle_since
        );
        match e.reason {
            EvictReason::Expired => assert!(
                (e.at - e.deadline).abs() < 1e-6,
                "{what}: TTL expiry at {} missed its policy deadline {}",
                e.at,
                e.deadline
            ),
            EvictReason::Pressure => assert!(
                e.at <= e.deadline + 1e-6,
                "{what}: pressure eviction at {} after its deadline {} (TTL should \
                 have fired first)",
                e.at,
                e.deadline
            ),
        }
    }
    assert_eq!(
        res.pressure_evictions,
        res.evictions.iter().filter(|e| e.reason == EvictReason::Pressure).count() as u64,
        "{what}: pressure counter drifted from the log"
    );
    assert_eq!(res.ready_miss, 0, "{what}");
    res.cluster.assert_warm_consistent();
    res.cluster.assert_admission_consistent();
}

/// Random-size cold asks from a deterministic per-seed policy.
struct RandomAsk {
    rng: Rng,
    max_vcpus: u32,
}

impl Policy for RandomAsk {
    fn name(&self) -> String {
        "random-ask".into()
    }
    fn on_request(&mut self, _now: SimTime, _req: &Request, cluster: &Cluster) -> Decision {
        Decision {
            worker: self.rng.below(cluster.len()),
            vcpus: self.rng.range_usize(1, self.max_vcpus as usize) as u32,
            mem_mb: (self.rng.range_usize(2, 32) as u32) * 128,
            container: ContainerChoice::Cold,
            background: None,
            overhead_s: 0.001,
        }
    }
}

#[test]
fn prop_evictions_respect_deadlines_and_never_touch_running_work() {
    // Random cluster shapes x random ask streams x all three keep-alive
    // policies. In this debug build the engine additionally
    // debug-asserts that every eviction victim `is_warm_idle()` and
    // re-checks `allocated <= limit` after every event; here we audit
    // the eviction log post-hoc: TTL expiries exactly at their policy
    // deadline, pressure evictions never after it, no record ever lost
    // (a `Starting`/`Busy` victim would lose its invocation), and both
    // consistency cross-checks hold under all three policies.
    prop::check(0x5E, 18, |rng| {
        let mode = match rng.below(3) {
            0 => KeepAliveMode::Fixed,
            1 => KeepAliveMode::Histogram,
            _ => KeepAliveMode::Pressure,
        };
        let workers = rng.range_usize(1, 3);
        let limit = rng.range_usize(12, 48) as f64;
        let keep_alive_s = rng.range_f64(2.0, 30.0);
        let n = rng.range_usize(10, 40);
        let reqs: Vec<Request> = (0..n as u64)
            .map(|i| {
                let at = rng.range_f64(0.0, 20.0);
                if rng.chance(0.5) {
                    qr_request(i + 1, at)
                } else {
                    compress_request(i + 1, at, rng.range_f64(16.0, 256.0))
                }
            })
            .collect();
        let cfg = SimConfig {
            workers,
            sched_vcpu_limit: limit,
            keep_alive_s,
            keepalive: mode,
            timeout_s: 60.0,
            ..SimConfig::default()
        };
        let res = if rng.chance(0.5) {
            // warm-reuse flavor: static asks revisit the pool
            let mut p = StaticPolicy::large(rng.next_u64());
            simulate(cfg, &mut p, reqs)
        } else {
            let mut p = RandomAsk { rng: Rng::new(rng.next_u64()), max_vcpus: 24 };
            simulate(cfg, &mut p, reqs)
        };
        audit_evictions(&res, n, &format!("{mode:?}"));
        assert!(res.cluster.peak_allocated_vcpus() <= limit);
    });
}

#[test]
fn parked_bind_is_admitted_via_pressure_eviction_with_queue_time() {
    // One worker that fits exactly one 16-vCPU container, three cold
    // 16-vCPU asks: under `pressure`, idle containers hold their
    // reservation, so each queued successor is admitted only when the
    // engine evicts the previous (idle) container for it — demand-driven
    // eviction on the admission path, with real queue time.
    let run = |mode: KeepAliveMode| {
        let cfg = SimConfig {
            workers: 1,
            sched_vcpu_limit: 16.0,
            keepalive: mode,
            ..SimConfig::default()
        };
        let mut p = SizedPolicy { vcpus: 16, mem_mb: 2048, next: 0, reuse_warm: true };
        // compress @ 512 MB ≈ 70 s of bounded-parallel work (maxpar 8):
        // request 1 runs ~[56, 102] s, request 2 parks behind it and runs
        // ~[56, 102] s more, so request 3 at t=105 arrives after request
        // 1's container went idle and while request 2 is still busy.
        let reqs = vec![
            compress_request(1, 0.0, 512.0),
            compress_request(2, 1.0, 512.0),
            compress_request(3, 105.0, 512.0),
        ];
        simulate(cfg, &mut p, reqs)
    };

    let pressure = run(KeepAliveMode::Pressure);
    audit_evictions(&pressure, 3, "pressure e2e");
    let rs = pressure.sorted_records();
    assert!(rs.iter().all(|r| r.verdict == Verdict::Completed));
    let r2 = rs.iter().find(|r| r.id == 2).unwrap();
    assert!(r2.queue_s > 0.0, "request 2 must park before its pressure admission");
    assert!(r2.had_cold_start, "admitted via eviction, not reuse");
    let r3 = rs.iter().find(|r| r.id == 3).unwrap();
    assert!(r3.queue_s > 0.0, "request 3 queues behind request 2");
    assert!(
        r3.had_cold_start,
        "pressure eviction reclaimed the warm pool: request 3 must cold-start"
    );
    assert_eq!(
        pressure.pressure_evictions, 2,
        "each queued admission evicted exactly one idle container"
    );
    for e in &pressure.evictions {
        if e.reason == EvictReason::Pressure {
            assert!(e.at < e.deadline, "pressure strikes before the TTL would");
        }
    }
    assert!(pressure.cluster.peak_allocated_vcpus() <= 16.0);

    // Contrast under `fixed`: the same workload queues the same way but
    // nothing is evicted early — request 3's decision finds the idle
    // warm container and reuses it.
    let fixed = run(KeepAliveMode::Fixed);
    audit_evictions(&fixed, 3, "fixed contrast");
    assert_eq!(fixed.pressure_evictions, 0);
    let rs = fixed.sorted_records();
    let r3 = rs.iter().find(|r| r.id == 3).unwrap();
    assert!(
        !r3.had_cold_start,
        "under fixed keep-alive request 3 reuses the warm container"
    );
    // hoarded warmth is the cost: fixed leaves far more idle
    // container-seconds than pressure on the identical workload
    assert!(
        fixed.idle_container_s > pressure.idle_container_s,
        "fixed {} vs pressure {} idle container-seconds",
        fixed.idle_container_s,
        pressure.idle_container_s
    );
}

#[test]
fn warm_bind_under_pressure_is_capacity_neutral() {
    // Reservation-holding idle must not block its *own* reuse: a warm
    // bind rolls the idle reservation over to busy, so it is admissible
    // even when the idle container fills the whole worker.
    let cfg = SimConfig {
        workers: 1,
        sched_vcpu_limit: 16.0,
        keepalive: KeepAliveMode::Pressure,
        ..SimConfig::default()
    };
    let mut p = SizedPolicy { vcpus: 16, mem_mb: 2048, next: 0, reuse_warm: true };
    let reqs = vec![qr_request(1, 0.0), qr_request(2, 30.0)];
    let res = simulate(cfg, &mut p, reqs);
    let rs = res.sorted_records();
    assert!(!rs[1].had_cold_start, "warm reuse must survive reservation-holding idle");
    assert_eq!(rs[1].queue_s, 0.0, "capacity-neutral: no parking for the warm bind");
    assert_eq!(res.pressure_evictions, 0);
    audit_evictions(&res, 2, "warm-neutral");
}

#[test]
fn histogram_short_tail_evicts_where_fixed_keeps_warm() {
    // keep_alive_eviction_forces_new_cold_start, histogram edition: 21
    // qr invocations 10 s apart train the inter-arrival histogram (gaps
    // all in one bin), shrinking the TTL to ~30 s; a straggler 300 s
    // later then cold-starts under `histogram` but warm-hits under the
    // 600 s `fixed` default.
    let run = |mode: KeepAliveMode| {
        let cfg = SimConfig { workers: 1, keepalive: mode, ..SimConfig::default() };
        let mut p = SizedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
        let mut reqs: Vec<Request> =
            (0..21).map(|i| qr_request(i + 1, i as f64 * 10.0)).collect();
        reqs.push(qr_request(22, 500.0));
        simulate(cfg, &mut p, reqs)
    };
    let hist = run(KeepAliveMode::Histogram);
    audit_evictions(&hist, 22, "histogram");
    let rs = hist.sorted_records();
    assert!(
        rs[21].had_cold_start,
        "bursty-trained histogram must have evicted the container long before t=500"
    );
    assert_eq!(hist.prewarm_launches, 0, "10 s gaps are below the pre-warm cutoff");

    let fixed = run(KeepAliveMode::Fixed);
    let rs = fixed.sorted_records();
    assert!(!rs[21].had_cold_start, "fixed 600 s TTL keeps the straggler warm");
    assert!(
        hist.idle_container_s < fixed.idle_container_s,
        "the shorter data-driven TTL must cut idle container-seconds: {} vs {}",
        hist.idle_container_s,
        fixed.idle_container_s
    );
}

#[test]
fn reuse_during_grace_window_cancels_the_pending_prewarm() {
    // A pre-warm only materializes when the eviction it compensates
    // actually fires: 9 long-gap arrivals train the histogram into
    // evict-then-pre-warm mode, then an *early* reuse 20 s after the 9th
    // (inside the 30 s grace window) bumps the idle epoch — the stale
    // eviction is skipped, and the pre-warm intent stored with it must
    // die too. The 20 s gap also drags the head percentile under the
    // cutoff, so later idle transitions use tail TTLs: no pre-warm may
    // ever launch in this run (the old schedule-at-idle design leaked
    // one here).
    let cfg =
        SimConfig { workers: 1, keepalive: KeepAliveMode::Histogram, ..SimConfig::default() };
    let mut p = SizedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
    let mut reqs: Vec<Request> = (0..9).map(|i| qr_request(i + 1, i as f64 * 120.0)).collect();
    reqs.push(qr_request(10, 980.0)); // early reuse, within the grace window
    reqs.push(qr_request(11, 1100.0));
    let res = simulate(cfg, &mut p, reqs);
    audit_evictions(&res, 11, "grace-reuse");
    assert_eq!(
        res.prewarm_launches, 0,
        "a reuse during the grace window must cancel the pending pre-warm"
    );
    let cold = res.records.iter().filter(|r| r.had_cold_start).count();
    assert_eq!(cold, 1, "only the very first invocation cold-starts");
}

#[test]
fn histogram_prewarms_predictable_long_gaps() {
    // keep_alive_eviction_forces_new_cold_start, pre-warm edition: gaps
    // of 120 s are past the pre-warm cutoff, so once trained the policy
    // gives containers up after a short grace window and launches a
    // replacement ~15 s before the expected next arrival — late
    // requests land warm *without* the container idling through the
    // whole gap.
    let cfg =
        SimConfig { workers: 1, keepalive: KeepAliveMode::Histogram, ..SimConfig::default() };
    let mut p = SizedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
    let reqs: Vec<Request> = (0..12).map(|i| qr_request(i + 1, i as f64 * 120.0)).collect();
    let res = simulate(cfg, &mut p, reqs);
    audit_evictions(&res, 12, "prewarm");
    assert!(res.prewarm_launches >= 1, "long predictable gaps must pre-warm");
    assert!(res.prewarm_hits >= 1, "a pre-warmed container must serve a request");
    let rs = res.sorted_records();
    let last = rs.last().unwrap();
    assert!(
        !last.had_cold_start,
        "the final request must land on a pre-warmed container"
    );
    // and the grace-window evictions really reclaimed the idle pool: no
    // container sat through a 120 s gap once the histogram was trained
    let fixed_cfg = SimConfig { workers: 1, ..SimConfig::default() };
    let mut p2 = SizedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
    let reqs2: Vec<Request> = (0..12).map(|i| qr_request(i + 1, i as f64 * 120.0)).collect();
    let fixed = simulate(fixed_cfg, &mut p2, reqs2);
    assert!(
        res.idle_container_s < fixed.idle_container_s,
        "evict-then-prewarm must idle less than holding through every gap: {} vs {}",
        res.idle_container_s,
        fixed.idle_container_s
    );
}
