//! Property tests over coordinator invariants (util::prop harness —
//! the offline build has no proptest crate; failures report their seed
//! for reproduction with `prop::check_one`).

use shabari::coordinator::allocator::cost::{
    self, class_mem_mb, class_vcpus, mem_class, vcpu_class, SlackPolicy,
};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::scheduler::Scheduler;
use shabari::featurizer::{FeatureVector, InputKind, InputSpec};
use shabari::functions::catalog::CATALOG;
use shabari::learner::{argmin, cost_vector};
use shabari::runtime::NUM_CLASSES;
use shabari::simulator::container::Container;
use shabari::simulator::worker::Cluster;
use shabari::simulator::{ContainerChoice, InvocationRecord, Request, SimConfig, Verdict};
use shabari::util::prop;
use shabari::util::rng::Rng;

fn random_record(rng: &mut Rng) -> InvocationRecord {
    let vcpus = rng.range_usize(1, 48) as u32;
    let alloc_mem = (rng.range_usize(2, 48) as u32) * 128;
    let exec = rng.range_f64(0.1, 120.0);
    let slo = rng.range_f64(0.1, 120.0);
    let peak = rng.range_f64(0.5, vcpus as f64);
    InvocationRecord {
        id: rng.next_u64(),
        func: rng.below(CATALOG.len()),
        input: InputSpec::new(InputKind::Payload),
        worker: 0,
        vcpus,
        mem_mb: alloc_mem,
        requested_vcpus: vcpus,
        requested_mem_mb: alloc_mem,
        arrival: 0.0,
        cold_start_s: 0.0,
        had_cold_start: rng.chance(0.3),
        overhead_s: 0.0,
        queue_s: 0.0,
        exec_s: exec,
        e2e_s: exec,
        end: exec,
        slo_s: slo,
        verdict: if rng.chance(0.9) { Verdict::Completed } else { Verdict::OomKilled },
        avg_vcpus_used: peak * rng.range_f64(0.3, 1.0),
        peak_vcpus_used: peak,
        mem_used_gb: rng.range_f64(0.05, alloc_mem as f64 / 1024.0),
    }
}

#[test]
fn prop_cost_vector_valid() {
    // minimum cost exactly 1 at the target; costs grow monotonically away
    prop::check(0xC0, 200, |rng| {
        let target = rng.below(NUM_CLASSES);
        let penalty = rng.range_f64(1.0, 6.0) as f32;
        let c = cost_vector(target, penalty);
        assert_eq!(argmin(&c), target);
        assert_eq!(c[target], 1.0);
        for i in 1..NUM_CLASSES {
            if i <= target {
                assert!(c[i - 1] >= c[i], "left side decreasing toward target");
            } else {
                assert!(c[i] >= c[i - 1], "right side increasing from target");
            }
        }
        assert!(c.iter().all(|v| *v >= 1.0));
    });
}

#[test]
fn prop_vcpu_target_in_range_and_sane() {
    prop::check(0xC1, 500, |rng| {
        let rec = random_record(rng);
        for policy in [SlackPolicy::absolute_default(), SlackPolicy::Proportional] {
            let t = cost::vcpu_target_class(&rec, policy);
            assert!(t < NUM_CLASSES);
            let target_vcpus = class_vcpus(t);
            let met = rec.verdict == Verdict::Completed && rec.exec_s <= rec.slo_s;
            if met {
                // never grow on a met SLO
                assert!(
                    target_vcpus <= rec.vcpus,
                    "met SLO must not grow: {} -> {}",
                    rec.vcpus,
                    target_vcpus
                );
            }
        }
    });
}

#[test]
fn prop_mem_target_covers_footprint() {
    prop::check(0xC2, 500, |rng| {
        let rec = random_record(rng);
        let t = cost::mem_target_class(&rec);
        if rec.verdict == Verdict::Completed {
            let target_mb = class_mem_mb(t) as f64;
            let used_mb = rec.mem_used_gb * 1024.0;
            assert!(
                target_mb + 1e-6 >= used_mb.min(cost::MAX_MEM_MB as f64 - 128.0),
                "target {target_mb} must cover footprint {used_mb}"
            );
        } else {
            // OOM kill: target strictly above the failed allocation
            assert!(class_mem_mb(t) > rec.mem_mb || rec.mem_mb >= cost::MAX_MEM_MB - 256);
        }
    });
}

#[test]
fn prop_class_encodings_roundtrip() {
    prop::check(0xC3, 200, |rng| {
        let v = rng.range_usize(1, 48) as u32;
        assert_eq!(class_vcpus(vcpu_class(v)), v);
        let m = (rng.range_usize(1, 48) as u32) * 128;
        assert_eq!(class_mem_mb(mem_class(m)), m);
    });
}

#[test]
fn prop_scheduler_never_routes_to_smaller_container() {
    prop::check(0xC4, 200, |rng| {
        let cfg = SimConfig::small();
        let mut cluster = Cluster::new(&cfg);
        // seed random warm containers
        let func = rng.below(CATALOG.len());
        for id in 1..=rng.range_usize(1, 8) as u64 {
            let vc = rng.range_usize(1, 32) as u32;
            let mem = (rng.range_usize(2, 32) as u32) * 128;
            let w = rng.below(cluster.len());
            let mut c = Container::new(id, func, vc, mem, 0.0);
            c.mark_ready(0.0);
            cluster.insert_container(w, c);
        }
        let vcpus = rng.range_usize(1, 32) as u32;
        let mem_mb = (rng.range_usize(2, 32) as u32) * 128;
        let req = Request {
            id: 1,
            func,
            input: InputSpec::new(CATALOG[func].input_kind),
            arrival: 0.0,
            slo_s: 1.0,
        };
        let mut s = ShabariScheduler::new(rng.next_u64());
        let d = s.schedule(&req, vcpus, mem_mb, &cluster);
        if let ContainerChoice::Warm(cid) = d.container {
            let c = cluster.workers[d.worker]
                .containers
                .get(&cid)
                .expect("routed container");
            assert!(c.vcpus >= vcpus && c.mem_mb >= mem_mb, "warm must be >= requested");
            assert_eq!(c.func, func);
            // background launch accompanies larger-warm routes only
            if c.vcpus == vcpus && c.mem_mb == mem_mb {
                assert!(d.background.is_none());
            }
        }
        assert!(d.worker < cluster.len());
    });
}

#[test]
fn prop_worker_rates_work_conserving() {
    use shabari::simulator::worker::{ActiveInv, Phase, PhaseSpec, Worker};
    prop::check(0xC5, 200, |rng| {
        let cfg = SimConfig::default();
        let mut w = Worker::new(0, &cfg);
        let n = rng.range_usize(1, 12);
        for i in 0..n {
            let demand = rng.range_f64(1.0, 48.0);
            let alloc = demand + rng.range_f64(0.0, 16.0);
            let inv = ActiveInv {
                inv_id: i as u64 + 1,
                container_id: i as u64 + 1,
                alloc_vcpus: alloc,
                remaining: 100.0,
                current: PhaseSpec { phase: Phase::Parallel, work: 100.0, demand },
                pending: vec![],
                cpu_seconds_done: 0.0,
                exec_started: 0.0,
                peak_vcpus: demand,
                mem_used_gb: 0.5,
            };
            w.start_invocation(inv, alloc.ceil() as u32, 512);
        }
        let rates = w.cpu_rates();
        let total: f64 = rates.values().sum();
        let demand_total: f64 = w.active.values().map(|a| a.current.demand).sum();
        // no invocation exceeds its demand
        for a in w.active.values() {
            assert!(rates[&a.inv_id] <= a.current.demand + 1e-9);
            assert!(rates[&a.inv_id] >= 0.0);
        }
        // work conserving up to the interference factor
        let cap = w.physical_cores.min(demand_total) * w.interference_factor();
        assert!(total <= cap + 1e-6, "total rate {total} exceeds capacity {cap}");
        if demand_total > w.physical_cores {
            assert!(
                total >= 0.9 * cap,
                "under contention capacity must be used: {total} vs {cap}"
            );
        }
    });
}

#[test]
fn prop_featurizer_stable_and_padded() {
    prop::check(0xC6, 300, |rng| {
        let kind = *rng.choose(InputKind::all());
        let mut s = InputSpec::new(kind);
        s.id = rng.next_u64() | 1;
        s.size_bytes = rng.range_f64(1.0, 3e9);
        s.width = rng.range_f64(16.0, 4000.0);
        s.height = rng.range_f64(16.0, 4000.0);
        s.rows = rng.range_f64(1.0, 1e7);
        s.cols = rng.range_f64(1.0, 64.0);
        s.duration_s = rng.range_f64(0.1, 900.0);
        s.bitrate = rng.range_f64(1e4, 1e7);
        s.length = rng.range_f64(1.0, 5e4);
        let a = shabari::featurizer::featurize(&s);
        let b = shabari::featurizer::featurize(&s);
        assert_eq!(a.vector, b.vector, "featurization deterministic");
        assert_eq!(a.vector.0[0], 1.0, "bias slot");
        assert_eq!(a.vector.0[FeatureVector::SLO_SLOT], 0.0, "slo slot empty");
        assert!(a.vector.0.iter().all(|v| v.is_finite()));
        assert!(a.extract_latency_s >= 0.0 && a.extract_latency_s < 0.1);
    });
}

#[test]
fn prop_every_arrival_terminates_exactly_once_under_faults() {
    // Conservation under adversity (DESIGN.md §Faults): whatever the
    // scheduler, keep-alive policy, or fault profile, every arrival in
    // the trace yields exactly one terminal record (Completed | OomKilled
    // | TimedOut | Failed) — nothing is dropped, nothing double-counted,
    // and the per-worker invariants hold at end of run. Each case is a
    // full mini-simulation, so the case count stays small; failures
    // report the case seed for `prop::check_one`.
    use shabari::experiments::common::{self, Ctx};
    use shabari::simulator::{faults, keepalive};
    prop::check(0xC8, 10, |rng| {
        let policy = *rng.choose(&["shabari", "shabari-ow-sched", "shabari-hermod"]);
        let ka = *rng.choose(&["fixed:120", "histogram", "pressure"]);
        let profile = *rng.choose(&["crash", "crash:20", "stragglers", "hetero", "chaos"]);
        let ctx = Ctx {
            seed: rng.next_u64(),
            duration_s: 60.0,
            keepalive: keepalive::parse(ka).unwrap(),
            faults: faults::parse(profile).unwrap(),
            ..Default::default()
        };
        let rps = 4.0;
        let workload = ctx.workload();
        let cfg = SimConfig { workers: 3, ..common::sim_config(&ctx) };
        let (res, _) = common::run_one(policy, &ctx, &workload, rps, &cfg).unwrap();
        // regenerate the (deterministic) trace to know exactly what arrived
        let scenario = ctx.build_scenario().unwrap();
        let trace = workload.trace_with(
            scenario.as_ref(),
            rps,
            ctx.duration_s,
            common::trace_seed(&ctx, rps),
        );
        let mut got: Vec<u64> = res.records.iter().map(|r| r.id).collect();
        got.sort_unstable();
        let mut want: Vec<u64> = trace.iter().map(|r| r.id).collect();
        want.sort_unstable();
        assert_eq!(
            got, want,
            "conservation broken under {policy}/{ka}/{profile}: \
             every arrival must produce exactly one terminal record"
        );
        res.cluster.check_invariants();
    });
}

#[test]
fn prop_demand_models_monotone_and_finite() {
    prop::check(0xC7, 100, |rng| {
        let func = &CATALOG[rng.below(CATALOG.len())];
        let pool = shabari::functions::inputs::pool(func, rng);
        for input in &pool {
            let d = (func.demand)(input);
            assert!(d.serial_s >= 0.0 && d.serial_s.is_finite());
            assert!(d.parallel_cpu_s >= 0.0 && d.parallel_cpu_s.is_finite());
            assert!(d.maxpar >= 1.0 && d.maxpar <= 48.0);
            assert!(d.mem_gb > 0.0 && d.mem_gb < 8.0);
            // more vCPUs never slower
            let mut prev = f64::INFINITY;
            for k in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 48.0] {
                let t = d.ideal_exec_s(k, 10.0);
                assert!(t <= prev + 1e-9);
                prev = t;
            }
        }
    });
}
