//! Engine-enforced admission control under overload (ISSUE 4 / DESIGN
//! §Admission): with queueing active, record streams must stay
//! byte-deterministic, FIFO pop order must hold through same-timestamp
//! capacity releases, reservations must never exceed the per-worker
//! limits at any event (the engine debug-asserts this after *every*
//! event in these builds), and a request must be able to die in queue
//! with a `TimedOut` record instead of a panic.

use shabari::baselines::StaticPolicy;
use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::featurizer::{InputKind, InputSpec};
use shabari::functions::catalog::index_of;
use shabari::simulator::engine::{simulate, SimResult};
use shabari::simulator::worker::Cluster;
use shabari::simulator::{
    ContainerChoice, Decision, Policy, Request, SimConfig, SimTime, Verdict,
};
use shabari::util::prop;
use shabari::util::rng::Rng;

fn qr_request(id: u64, at: f64) -> Request {
    let mut input = InputSpec::new(InputKind::Payload);
    input.length = 100.0;
    input.size_bytes = 100.0;
    Request { id, func: index_of("qr").unwrap(), input, arrival: at, slo_s: 1.0 }
}

fn compress_request(id: u64, at: f64, mb: f64) -> Request {
    let mut input = InputSpec::new(InputKind::File);
    input.id = id | 1;
    input.size_bytes = mb * 1024.0 * 1024.0;
    Request { id, func: index_of("compress").unwrap(), input, arrival: at, slo_s: 60.0 }
}

/// A saturating burst: 3 waves of simultaneous large static asks onto a
/// single worker — admission must queue most of each wave.
fn overload_run(seed: u64) -> SimResult {
    let reqs: Vec<Request> = (0..3u64)
        .flat_map(|wave| {
            (0..15u64).map(move |i| {
                let id = wave * 15 + i + 1;
                qr_request(id, wave as f64 * 10.0)
            })
        })
        .collect();
    let mut p = StaticPolicy::large(seed);
    let cfg = SimConfig { workers: 1, ..SimConfig::default() };
    simulate(cfg, &mut p, reqs)
}

#[test]
fn queueing_run_is_byte_deterministic() {
    let fingerprint = |res: &SimResult| -> Vec<(u64, u64, u64, u64, bool)> {
        res.records
            .iter()
            .map(|r| {
                (
                    r.id,
                    r.queue_s.to_bits(),
                    r.exec_s.to_bits(),
                    r.e2e_s.to_bits(),
                    r.verdict == Verdict::Completed,
                )
            })
            .collect()
    };
    let a = overload_run(7);
    let b = overload_run(7);
    assert_eq!(a.records.len(), 45, "every request produces a record");
    assert_eq!(
        fingerprint(&a),
        fingerprint(&b),
        "ordered record streams diverged across identical runs with queueing active"
    );
    // the burst really exercised the queue
    let queued = a.records.iter().filter(|r| r.queue_s > 0.0).count();
    assert!(queued > 10, "15 x 20-vCPU asks on a 90-vCPU worker must queue: {queued}");
    a.cluster.assert_admission_consistent();
    a.cluster.assert_warm_consistent();
}

#[test]
fn fifo_pop_order_holds_through_tied_releases() {
    // Identical invocations completing under processor sharing produce
    // batches of same-timestamp capacity releases; the queue must still
    // drain in enqueue order. Enqueue order on one worker is BeginExec
    // order — (arrival + overhead), ties by id — and an entry leaves the
    // queue at enqueue + queue_s, so pop times must be non-decreasing in
    // that order (invocations admitted without queueing pop at their
    // begin time, which FIFO also orders: the queue was empty then).
    let res = overload_run(11);
    let mut by_enqueue: Vec<(f64, u64, f64)> = res
        .records
        .iter()
        .map(|r| (r.arrival + r.overhead_s, r.id, r.arrival + r.overhead_s + r.queue_s))
        .collect();
    by_enqueue.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    for pair in by_enqueue.windows(2) {
        assert!(
            pair[1].2 >= pair[0].2 - 1e-9,
            "FIFO violated: id {} popped at {} but later-enqueued id {} popped at {}",
            pair[0].1,
            pair[0].2,
            pair[1].1,
            pair[1].2
        );
    }
}

#[test]
fn shabari_stack_stays_deterministic_under_queueing() {
    // The full coordinator (learner feedback order matters) on an
    // overloaded single worker: queue-induced reordering must not leak
    // nondeterminism into the record stream or the model state.
    let run = || {
        let reqs: Vec<Request> =
            (0..30).map(|i| compress_request(i + 1, (i / 10) as f64 * 5.0, 256.0)).collect();
        let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
        let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(3)));
        let cfg = SimConfig { workers: 1, sched_vcpu_limit: 48.0, ..SimConfig::default() };
        let res = simulate(cfg, &mut policy, reqs);
        res.records
            .iter()
            .map(|r| (r.id, r.queue_s.to_bits(), r.e2e_s.to_bits(), r.vcpus))
            .collect::<Vec<_>>()
    };
    let a = run();
    assert_eq!(a.len(), 30);
    assert_eq!(a, run(), "coordinator stream diverged under admission queueing");
}

/// Random-size cold asks from a deterministic per-seed policy.
struct RandomAsk {
    rng: Rng,
    max_vcpus: u32,
}

impl Policy for RandomAsk {
    fn name(&self) -> String {
        "random-ask".into()
    }
    fn on_request(&mut self, _now: SimTime, _req: &Request, cluster: &Cluster) -> Decision {
        Decision {
            worker: self.rng.below(cluster.len()),
            vcpus: self.rng.range_usize(1, self.max_vcpus as usize) as u32,
            mem_mb: (self.rng.range_usize(2, 32) as u32) * 128,
            container: ContainerChoice::Cold,
            background: None,
            overhead_s: 0.001,
        }
    }
}

#[test]
fn prop_reservations_never_exceed_limits_after_any_event() {
    // Random cluster shapes x random ask streams. The engine
    // debug-asserts `allocated <= limit` after *every* event in this
    // build; the per-worker peaks re-verify it here (as in release), and
    // the full container-state cross-check catches accounting drift.
    prop::check(0xAD, 25, |rng| {
        let workers = rng.range_usize(1, 3);
        let limit = rng.range_usize(12, 48) as f64;
        let mem_gb = rng.range_usize(8, 64) as f64;
        let n = rng.range_usize(10, 40);
        let max_vcpus = rng.range_usize(4, 32) as u32;
        let reqs: Vec<Request> = (0..n as u64)
            .map(|i| {
                let at = rng.range_f64(0.0, 10.0);
                if rng.chance(0.5) {
                    qr_request(i + 1, at)
                } else {
                    compress_request(i + 1, at, rng.range_f64(16.0, 256.0))
                }
            })
            .collect();
        let mut p = RandomAsk { rng: Rng::new(rng.next_u64()), max_vcpus };
        let cfg = SimConfig {
            workers,
            sched_vcpu_limit: limit,
            mem_gb,
            timeout_s: 30.0,
            ..SimConfig::default()
        };
        let res = simulate(cfg, &mut p, reqs);
        assert_eq!(res.records.len(), n, "every request reaches a terminal record");
        assert!(
            res.cluster.peak_allocated_vcpus() <= limit,
            "peak {} exceeded limit {limit}",
            res.cluster.peak_allocated_vcpus()
        );
        assert!(res.cluster.peak_allocated_mem_mb() <= mem_gb * 1024.0);
        res.cluster.assert_admission_consistent();
        res.cluster.assert_warm_consistent();
        // asks larger than the limit can never bind: they must surface as
        // clean in-queue timeouts, not panics or silent admissions
        for r in &res.records {
            if r.requested_vcpus as f64 > limit {
                assert_eq!(r.verdict, Verdict::TimedOut, "oversized ask id {}", r.id);
                assert_eq!(r.exec_s, 0.0);
            }
        }
    });
}

#[test]
fn saturated_cluster_times_out_queued_tail_without_panic() {
    // 25 large asks at t=0 against one worker that fits four (each round
    // of service takes ~5 s), with a 15 s walltime limit: most of the
    // tail cannot possibly be served and must die waiting.
    let reqs: Vec<Request> = (0..25).map(|i| compress_request(i + 1, 0.0, 1024.0)).collect();
    let mut p = StaticPolicy::large(5);
    let cfg = SimConfig { workers: 1, timeout_s: 15.0, ..SimConfig::default() };
    let res = simulate(cfg, &mut p, reqs);
    assert_eq!(res.records.len(), 25);
    let died_in_queue: Vec<_> = res
        .records
        .iter()
        .filter(|r| r.verdict == Verdict::TimedOut && r.exec_s == 0.0 && r.queue_s > 0.0)
        .collect();
    assert!(
        !died_in_queue.is_empty(),
        "the queued tail must produce TimedOut records (exec 0, queue_s > 0)"
    );
    for r in &died_in_queue {
        assert!((r.e2e_s - 15.0).abs() < 1e-6, "walltime counted from arrival");
        assert!(r.queue_s <= 15.0 + 1e-9);
    }
    res.cluster.assert_admission_consistent();
}
