//! Integration: load the real AOT artifacts through PJRT and check numerics
//! against hand-computed CSOAA math. This is the L3<->L2/L1 contract test.
//!
//! Needs the `xla` feature (and `make artifacts`); the default build
//! compiles this file to an empty test crate.
#![cfg(feature = "xla")]

use shabari::runtime::{XlaEngine, BATCH, FEAT_DIM, NUM_CLASSES};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Deterministic pseudo-random fill (no rand crate needed here).
fn fill(v: &mut [f32], mut seed: u64) {
    for x in v.iter_mut() {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *x = ((seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
    }
}

#[test]
fn predict_matches_host_matvec() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let eng = XlaEngine::load_dir(artifacts_dir()).expect("load artifacts");
    let (c, f) = (NUM_CLASSES, FEAT_DIM);
    let mut w = vec![0f32; c * f];
    let mut x = vec![0f32; f];
    fill(&mut w, 1);
    fill(&mut x, 2);

    let out = eng
        .execute_f32(
            "csmc_predict",
            &[(&w, &[c as i64, f as i64]), (&x, &[f as i64])],
        )
        .expect("execute predict");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), c);

    for i in 0..c {
        let expect: f32 = (0..f).map(|j| w[i * f + j] * x[j]).sum();
        let got = out[0][i];
        assert!(
            (expect - got).abs() <= 1e-5 * (1.0 + expect.abs()),
            "class {i}: host {expect} vs xla {got}"
        );
    }
}

#[test]
fn update_matches_host_sgd() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let eng = XlaEngine::load_dir(artifacts_dir()).expect("load artifacts");
    let (c, f) = (NUM_CLASSES, FEAT_DIM);
    let mut w = vec![0f32; c * f];
    let mut x = vec![0f32; f];
    let mut costs = vec![0f32; c];
    fill(&mut w, 3);
    fill(&mut x, 4);
    fill(&mut costs, 5);
    let lr = 0.05f32;

    let out = eng
        .execute_f32(
            "csmc_update",
            &[
                (&w, &[c as i64, f as i64]),
                (&x, &[f as i64]),
                (&costs, &[c as i64]),
                (&[lr], &[]),
            ],
        )
        .expect("execute update");
    assert_eq!(out[0].len(), c * f);

    for i in 0..c {
        let pred: f32 = (0..f).map(|j| w[i * f + j] * x[j]).sum();
        let err = pred - costs[i];
        for j in 0..f {
            let expect = w[i * f + j] - lr * err * x[j];
            let got = out[0][i * f + j];
            assert!(
                (expect - got).abs() <= 1e-5 * (1.0 + expect.abs()),
                "w[{i},{j}]: host {expect} vs xla {got}"
            );
        }
    }
}

#[test]
fn predict_batch_matches_host() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let eng = XlaEngine::load_dir(artifacts_dir()).expect("load artifacts");
    let (c, f, b) = (NUM_CLASSES, FEAT_DIM, BATCH);
    let mut w = vec![0f32; c * f];
    let mut xs = vec![0f32; b * f];
    fill(&mut w, 6);
    fill(&mut xs, 7);

    let out = eng
        .execute_f32(
            "csmc_predict_batch",
            &[(&w, &[c as i64, f as i64]), (&xs, &[b as i64, f as i64])],
        )
        .expect("execute batch predict");
    assert_eq!(out[0].len(), b * c);

    // Spot-check a grid of entries (full check is O(B*C*F), fine too).
    for bi in (0..b).step_by(7) {
        for ci in (0..c).step_by(5) {
            let expect: f32 = (0..f).map(|j| xs[bi * f + j] * w[ci * f + j]).sum();
            let got = out[0][bi * c + ci];
            assert!(
                (expect - got).abs() <= 1e-5 * (1.0 + expect.abs()),
                "[{bi},{ci}]: host {expect} vs xla {got}"
            );
        }
    }
}

#[test]
fn engine_rejects_wrong_arity() {
    if !have_artifacts() {
        return;
    }
    let eng = XlaEngine::load_dir(artifacts_dir()).expect("load artifacts");
    let err = eng.execute_f32("csmc_predict", &[(&[0f32; 16], &[16])]);
    assert!(err.is_err(), "arity mismatch must error");
}

#[test]
fn engine_rejects_unknown_name() {
    if !have_artifacts() {
        return;
    }
    let eng = XlaEngine::load_dir(artifacts_dir()).expect("load artifacts");
    assert!(eng.execute_f32("nope", &[]).is_err());
}
