//! Trace-battery integration tests (DESIGN.md §Observability): a traced
//! run must (a) attribute every second of every invocation's life to
//! exactly one span component, telescoping to the recorded end-to-end
//! latency, (b) export losslessly to JSONL and to valid Chrome
//! trace-event JSON, (c) produce byte-identical trace files regardless
//! of `--jobs`, and (d) sample timelines that respect the admission
//! invariants. The companion determinism pin (tracing *off* is
//! byte-identical) lives in `test_determinism.rs`.

use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::experiments::common::{self, Ctx, TraceOut};
use shabari::experiments::sweep;
use shabari::functions::catalog::{index_of, CATALOG};
use shabari::functions::inputs;
use shabari::simulator::engine::{simulate, SimResult};
use shabari::simulator::trace::{TraceConfig, TraceEventKind, TraceLog};
use shabari::simulator::{Request, SimConfig};
use shabari::util::json::{self, Json};
use shabari::util::rng::Rng;

/// 3 waves x 20 simultaneous invocations on one worker: guaranteed
/// queueing, cold starts, and same-timestamp event batches.
fn tie_heavy_requests() -> Vec<Request> {
    let fi = index_of("qr").unwrap();
    let mut rng = Rng::new(11);
    let pool = inputs::pool(&CATALOG[fi], &mut rng);
    let mut reqs = Vec::new();
    for wave in 0..3u64 {
        for i in 0..20u64 {
            let id = wave * 20 + i + 1;
            reqs.push(Request {
                id,
                func: fi,
                input: pool[(id as usize) % pool.len()].clone(),
                arrival: wave as f64 * 15.0,
                slo_s: 1.0,
            });
        }
    }
    reqs
}

fn traced_run() -> SimResult {
    let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
    let mut policy = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(7)));
    let cfg = SimConfig {
        workers: 1,
        trace: Some(TraceConfig { sample_interval_s: 5.0 }),
        ..SimConfig::default()
    };
    simulate(cfg, &mut policy, tie_heavy_requests())
}

#[test]
fn spans_telescope_to_e2e_for_every_invocation() {
    let res = traced_run();
    let log = res.trace.as_ref().expect("tracing was on");
    let spans = log.spans();
    assert_eq!(spans.len(), res.records.len(), "one span chain per record");
    let mut queued = 0usize;
    let mut cold = 0usize;
    for s in &spans {
        let err = (s.components_sum() - s.e2e_s()).abs();
        assert!(
            err < 1e-9,
            "invocation {}: decision {} + queue {} + cold {} + exec {} != e2e {} (err {err})",
            s.inv,
            s.decision_s,
            s.queue_s,
            s.cold_start_s,
            s.exec_s,
            s.e2e_s()
        );
        assert!(s.decision_s >= 0.0 && s.queue_s >= 0.0);
        assert!(s.cold_start_s >= 0.0 && s.exec_s >= 0.0);
        queued += (s.queue_s > 0.0) as usize;
        cold += (s.cold_start_s > 0.0) as usize;
    }
    // 20 simultaneous arrivals on one cold worker: both components are
    // exercised for real, not vacuously zero
    assert!(queued > 0, "tie-heavy load must queue someone");
    assert!(cold > 0, "first wave hits a cold worker");
    // spans agree with the engine's own records on end-to-end latency
    // (the records' e2e_s is the ground truth the components must cover)
    for r in &res.records {
        let s = spans.iter().find(|s| s.inv == r.id).expect("span chain for record");
        assert!(
            (s.e2e_s() - r.e2e_s).abs() < 1e-9,
            "invocation {}: span e2e {} != record e2e {}",
            r.id,
            s.e2e_s(),
            r.e2e_s
        );
    }
}

#[test]
fn event_stream_is_consistent_with_the_record_stream() {
    let res = traced_run();
    let log = res.trace.as_ref().unwrap();
    let count = |f: &dyn Fn(&TraceEventKind) -> bool| {
        log.events.iter().filter(|e| f(&e.kind)).count()
    };
    let arrivals = count(&|k| matches!(k, TraceEventKind::Arrival { .. }));
    let decisions = count(&|k| matches!(k, TraceEventKind::Decision { .. }));
    let ends = count(&|k| matches!(k, TraceEventKind::End { .. }));
    let execs = count(&|k| matches!(k, TraceEventKind::ExecBegin { .. }));
    assert_eq!(arrivals, res.records.len(), "one Arrival per record");
    assert_eq!(decisions, res.records.len(), "one Decision per record");
    assert_eq!(ends, res.records.len(), "one terminal event per record");
    assert!(execs <= res.records.len(), "at most one ExecBegin per invocation");
    // timestamps never run backwards (the engine records in event order)
    for pair in log.events.windows(2) {
        assert!(pair[0].at <= pair[1].at, "out-of-order trace events");
    }
}

#[test]
fn jsonl_and_chrome_exports_are_valid_and_lossless() {
    let res = traced_run();
    let log = res.trace.as_ref().unwrap();
    // JSONL: every line parses; the round trip is byte-identical
    let jsonl = log.to_jsonl();
    for (i, line) in jsonl.lines().enumerate() {
        json::parse(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
    }
    let reparsed = TraceLog::from_jsonl(&jsonl).unwrap();
    assert_eq!(reparsed.to_jsonl(), jsonl, "JSONL round trip must be lossless");
    assert_eq!(reparsed.spans().len(), log.spans().len());
    // Chrome export: valid JSON, worker tracks + spans present
    let chrome = log.to_chrome();
    let j = json::parse(&chrome).unwrap();
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty());
    let phases: Vec<&str> =
        events.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
    assert!(phases.contains(&"M"), "process_name metadata for worker tracks");
    assert!(phases.contains(&"X"), "complete events for invocation spans");
    assert!(phases.contains(&"C"), "counter events for utilization");
}

#[test]
fn repeated_traced_runs_are_byte_identical() {
    let a = traced_run();
    let b = traced_run();
    let (ta, tb) = (a.trace.unwrap(), b.trace.unwrap());
    assert!(!ta.events.is_empty());
    assert_eq!(ta.to_jsonl(), tb.to_jsonl(), "JSONL bytes diverged across identical runs");
    assert_eq!(ta.to_chrome(), tb.to_chrome(), "Chrome bytes diverged across identical runs");
}

#[test]
fn timeline_samples_respect_admission_invariants() {
    let res = traced_run();
    let log = res.trace.as_ref().unwrap();
    assert!(!log.samples.is_empty(), "a multi-wave run spans several intervals");
    for (i, s) in log.samples.iter().enumerate() {
        assert!(
            (s.at - i as f64 * 5.0).abs() < 1e-9 || i + 1 == log.samples.len(),
            "sample {i} at {} off the 5s grid",
            s.at
        );
        for w in &s.workers {
            assert!(w.busy_vcpus <= w.allocated_vcpus + 1e-9, "busy exceeds reservations");
            assert!(w.allocated_vcpus <= w.vcpu_limit + 1e-9, "reservations exceed the limit");
            assert!(w.allocated_mem_mb <= w.mem_limit_mb + 1e-9, "memory exceeds the limit");
        }
    }
}

#[test]
fn trace_files_are_byte_identical_across_jobs() {
    let base = std::env::temp_dir().join(format!("shabari-trace-jobs-{}", std::process::id()));
    let run = |jobs: usize, tag: &str| -> (String, Vec<u8>) {
        let dir = base.join(tag);
        std::fs::create_dir_all(&dir).unwrap();
        let ctx = Ctx {
            duration_s: 60.0,
            seeds: 2,
            jobs,
            trace: Some(TraceOut {
                jsonl: Some(dir.join("t.jsonl").to_string_lossy().into_owned()),
                chrome: None,
                interval_s: 10.0,
                exact: false,
            }),
            ..Default::default()
        };
        let cells = [sweep::Cell::new("static-medium", 2.0)];
        sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
            common::run_cell(&cell.policy, &ctx, cell.rps, seed)
        })
        .unwrap();
        // replicate-0 gating: exactly one traced replicate -> one file
        let mut files: Vec<_> =
            std::fs::read_dir(&dir).unwrap().map(|e| e.unwrap().path()).collect();
        assert_eq!(files.len(), 1, "expected exactly one trace file, got {files:?}");
        let path = files.pop().unwrap();
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        (name, std::fs::read(&path).unwrap())
    };
    let (name_a, bytes_a) = run(1, "a");
    let (name_b, bytes_b) = run(4, "b");
    assert!(!bytes_a.is_empty());
    assert_eq!(name_a, name_b, "cell-derived trace names must not depend on --jobs");
    assert!(name_a.starts_with("t-static-medium-2"), "{name_a}");
    assert_eq!(bytes_a, bytes_b, "trace bytes diverged across --jobs");
    std::fs::remove_dir_all(&base).ok();
}
