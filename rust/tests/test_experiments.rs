//! Experiment smoke tests: every figure/table runner executes, and the
//! paper's qualitative shapes (DESIGN.md §4) hold on scaled-down sweeps.

use shabari::experiments::common::{run_one, sim_config, Ctx};
use shabari::experiments::{self};

fn quick_ctx() -> Ctx {
    Ctx { duration_s: 180.0, ..Default::default() }
}

#[test]
fn characterization_experiments_run() {
    let ctx = quick_ctx();
    for id in ["fig1", "fig3", "fig4", "table1", "table2"] {
        experiments::run(id, &ctx).unwrap_or_else(|e| panic!("{id}: {e}"));
    }
}

#[test]
fn fig6_formulation_shapes() {
    // per-function beats one-hot on idle vCPUs (paper: ~5x p90 gap)
    let ctx = quick_ctx();
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let (_, per_func) = run_one("shabari", &ctx, &w, 4.0, &cfg).unwrap();
    let (_, onehot) = run_one("shabari-onehot", &ctx, &w, 4.0, &cfg).unwrap();
    assert!(
        onehot.wasted_vcpus.p90 >= per_func.wasted_vcpus.p90,
        "one-hot must waste at least as many p90 vCPUs: {} vs {}",
        onehot.wasted_vcpus.p90,
        per_func.wasted_vcpus.p90
    );
}

#[test]
fn fig8_headline_shapes() {
    let ctx = quick_ctx();
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let names = ["shabari", "static-large", "parrotfish", "cypress"];
    let mut m = std::collections::HashMap::new();
    for n in names {
        let (_, metrics) = run_one(n, &ctx, &w, 5.0, &cfg).unwrap();
        m.insert(n, metrics);
    }
    // Shabari beats every baseline on violations at high load
    for other in ["static-large", "parrotfish", "cypress"] {
        assert!(
            m["shabari"].slo_violation_pct < m[other].slo_violation_pct,
            "shabari {} vs {other} {}",
            m["shabari"].slo_violation_pct,
            m[other].slo_violation_pct
        );
    }
    // median wasted vCPUs ~0 (headline claim)
    assert!(m["shabari"].wasted_vcpus.p50 <= 1.0);
    // Parrotfish wastes several times Shabari's median memory
    assert!(
        m["parrotfish"].wasted_mem_gb.p50 > 0.0
            || m["shabari"].wasted_mem_gb.p50 <= m["parrotfish"].wasted_mem_gb.p50 + 0.5
    );
}

#[test]
fn fig10_cold_start_shape() {
    // Shabari's scheduler cuts cold-start fraction vs the OW scheduler
    let ctx = quick_ctx();
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let (_, shabari) = run_one("shabari", &ctx, &w, 5.0, &cfg).unwrap();
    let (_, ow) = run_one("shabari-ow-sched", &ctx, &w, 5.0, &cfg).unwrap();
    assert!(
        shabari.cold_start_pct < ow.cold_start_pct,
        "{} vs {}",
        shabari.cold_start_pct,
        ow.cold_start_pct
    );
    assert!(shabari.background_launches > 0, "proactive launches must fire");
}

#[test]
fn table3_multi_threaded_explore_more_sizes() {
    let ctx = quick_ctx();
    let w = ctx.workload();
    let cfg = sim_config(&ctx);
    let (res, _) = run_one("shabari", &ctx, &w, 5.0, &cfg).unwrap();
    let idx = shabari::functions::catalog::index_of;
    let matmult = res.unique_container_sizes(idx("matmult").unwrap());
    let qr = res.unique_container_sizes(idx("qr").unwrap());
    assert!(
        matmult > qr,
        "multi-threaded functions explore more container sizes: matmult {matmult} vs qr {qr}"
    );
}

#[test]
fn fig11_oversubscription_monotone_timeouts() {
    use shabari::coordinator::allocator::ResourceAllocator;
    use shabari::coordinator::scheduler::shabari::ShabariScheduler;
    use shabari::coordinator::ShabariPolicy;
    use shabari::metrics::from_result;
    use shabari::simulator::engine::simulate;

    let ctx = quick_ctx();
    let w = ctx.workload();
    let run = |limit: f64| {
        let mut cfg = sim_config(&ctx);
        cfg.sched_vcpu_limit = limit;
        let alloc = ResourceAllocator::new(ctx.allocator_cfg()).unwrap();
        let mut p = ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(3)));
        let trace = w.trace(6.0, ctx.duration_s, 44);
        from_result("s", &simulate(cfg, &mut p, trace))
    };
    let m90 = run(90.0);
    let m130 = run(130.0);
    assert!(
        m130.timeout_pct + m130.slo_violation_pct >= m90.timeout_pct,
        "higher oversubscription cannot reduce timeouts to nothing"
    );
}

#[test]
fn unknown_experiment_rejected() {
    assert!(experiments::run("fig999", &quick_ctx()).is_err());
}
