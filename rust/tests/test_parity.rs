//! Native-vs-XLA learner parity: both backends implement the same CSOAA
//! math; after identical update sequences their weights and predictions
//! must agree to f32 round-off. This pins the rust mirror to the
//! Pallas/JAX ground truth end-to-end (through the real artifacts).
//!
//! Needs the `xla` feature (and `make artifacts`); the default build
//! compiles this file to an empty test crate.
#![cfg(feature = "xla")]

use std::rc::Rc;

use shabari::learner::native::NativeCsmc;
use shabari::learner::xla::XlaCsmc;
use shabari::learner::{cost_vector, CsmcModel};
use shabari::runtime::{XlaEngine, FEAT_DIM, NUM_CLASSES};
use shabari::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn rand_x(rng: &mut Rng) -> [f32; FEAT_DIM] {
    let mut x = [0f32; FEAT_DIM];
    for v in x.iter_mut() {
        *v = rng.range_f64(-1.0, 1.0) as f32;
    }
    x[0] = 1.0;
    x
}

#[test]
fn weights_match_after_update_sequence() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Rc::new(XlaEngine::load_dir(artifacts_dir()).unwrap());
    let mut xla = XlaCsmc::new(engine, 0.05);
    let mut native = NativeCsmc::new(0.05);
    let mut rng = Rng::new(42);

    for step in 0..50 {
        let x = rand_x(&mut rng);
        let target = rng.below(NUM_CLASSES);
        let costs = cost_vector(target, 2.0);
        xla.update(&x, &costs);
        native.update(&x, &costs);

        if step % 10 == 9 {
            for (i, (a, b)) in xla.weights().iter().zip(native.w.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                    "step {step}, w[{i}]: xla {a} vs native {b}"
                );
            }
        }
    }
}

#[test]
fn predictions_match() {
    if !have_artifacts() {
        return;
    }
    let engine = Rc::new(XlaEngine::load_dir(artifacts_dir()).unwrap());
    let mut xla = XlaCsmc::new(engine, 0.05);
    let mut native = NativeCsmc::new(0.05);
    let mut rng = Rng::new(7);

    // train both on the same stream
    for _ in 0..60 {
        let x = rand_x(&mut rng);
        let costs = cost_vector(rng.below(NUM_CLASSES), 2.0);
        xla.update(&x, &costs);
        native.update(&x, &costs);
    }
    // predictions agree on fresh inputs
    for _ in 0..20 {
        let x = rand_x(&mut rng);
        assert_eq!(xla.predict(&x), native.predict(&x));
    }
}

#[test]
fn batch_scores_match_singles() {
    if !have_artifacts() {
        return;
    }
    let engine = Rc::new(XlaEngine::load_dir(artifacts_dir()).unwrap());
    let mut xla = XlaCsmc::new(engine, 0.05);
    let mut rng = Rng::new(9);
    for _ in 0..20 {
        let x = rand_x(&mut rng);
        xla.update(&x, &cost_vector(rng.below(NUM_CLASSES), 2.0));
    }
    // batched artifact has fixed B=64
    let xs: Vec<[f32; FEAT_DIM]> =
        (0..shabari::runtime::BATCH).map(|_| rand_x(&mut rng)).collect();
    let flat: Vec<f32> = xs.iter().flat_map(|x| x.iter().copied()).collect();
    let batch = xla.scores_batch(&flat).unwrap();
    for (bi, x) in xs.iter().enumerate() {
        let single = xla.scores(x);
        for c in 0..NUM_CLASSES {
            let a = batch[bi * NUM_CLASSES + c];
            let b = single[c];
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "[{bi},{c}]: batch {a} vs single {b}"
            );
        }
    }
}

#[test]
fn learned_behaviour_matches_convergence() {
    if !have_artifacts() {
        return;
    }
    let engine = Rc::new(XlaEngine::load_dir(artifacts_dir()).unwrap());
    let mut xla = XlaCsmc::new(engine, 0.1);
    let mut rng = Rng::new(21);
    let x = rand_x(&mut rng);
    let costs = cost_vector(33, 2.0);
    for _ in 0..150 {
        xla.update(&x, &costs);
    }
    assert_eq!(xla.predict(&x), 33, "XLA learner must converge to target class");
}
