//! Determinism-linter battery (DESIGN.md §Static analysis).
//!
//! Per-rule positive/negative fixtures as embedded strings (no temp-file
//! nondeterminism), the `lint:allow` escape semantics, and the self-check
//! that the repo tree itself is lint-clean — which is exactly what the CI
//! gate (`cargo run --release -- lint`) enforces.

use std::path::Path;

use shabari::analysis::{lint_source, lint_tree, report, LintOutcome};

/// Rules fired on a fixture, in report order.
fn rules_of(out: &LintOutcome) -> Vec<&str> {
    out.violations.iter().map(|v| v.rule.as_str()).collect()
}

// ---------------------------------------------------------------- D001

#[test]
fn d001_flags_hash_collections_in_scoped_paths() {
    let src = "use std::collections::HashMap;\n";
    for dir in ["simulator", "coordinator", "learner", "metrics"] {
        let out = lint_source(&format!("src/{dir}/x.rs"), src);
        assert_eq!(rules_of(&out), vec!["D001"], "{dir}");
    }
    let out = lint_source("src/simulator/x.rs", "fn f(s: &mut HashSet<u32>) {}\n");
    assert_eq!(rules_of(&out), vec!["D001"]);
}

#[test]
fn d001_ignores_unscoped_paths_and_sorted_collections() {
    let src = "use std::collections::HashMap;\n";
    assert!(lint_source("src/util/x.rs", src).is_clean());
    assert!(lint_source("tests/test_x.rs", src).is_clean());
    let sorted = "use std::collections::{BTreeMap, BTreeSet};\n";
    assert!(lint_source("src/simulator/x.rs", sorted).is_clean());
}

#[test]
fn d001_exempts_test_regions_and_string_literals() {
    let test_only = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
    assert!(lint_source("src/simulator/x.rs", test_only).is_clean());
    let in_str = "const MSG: &str = \"HashMap order leaks\";\n";
    assert!(lint_source("src/simulator/x.rs", in_str).is_clean());
}

// ---------------------------------------------------------------- D002

#[test]
fn d002_flags_wall_clock_reads() {
    let src = "fn f() { let t0 = std::time::Instant::now(); }\n";
    let out = lint_source("src/metrics/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D002"]);
    let sys = "fn f() { let t = std::time::SystemTime::now(); }\n";
    assert_eq!(rules_of(&lint_source("src/util/x.rs", sys)), vec!["D002"]);
}

#[test]
fn d002_exempts_bench_paths_tests_and_bare_imports() {
    let src = "fn f() { let t0 = std::time::Instant::now(); }\n";
    assert!(lint_source("src/util/bench.rs", src).is_clean());
    assert!(lint_source("benches/bench_x.rs", src).is_clean());
    let test_only = format!("#[cfg(test)]\nmod tests {{\n    {src}\n}}\n");
    assert!(lint_source("src/simulator/x.rs", &test_only).is_clean());
    // importing the type is fine; only the `::now` read is a violation
    assert!(lint_source("src/simulator/x.rs", "use std::time::Instant;\n").is_clean());
}

// ---------------------------------------------------------------- D003

#[test]
fn d003_flags_inline_rng_salts() {
    let src = "fn f(seed: u64) { let r = Rng::new(seed ^ 0x5115_BA71); }\n";
    let out = lint_source("src/workload/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D003"]);
    // literal-first order is the same violation
    let flipped = "fn f(seed: u64) { let r = Rng::new(0xABC ^ seed); }\n";
    assert_eq!(rules_of(&lint_source("src/workload/x.rs", flipped)), vec!["D003"]);
}

#[test]
fn d003_accepts_named_salts_plain_seeds_and_hashes() {
    let named = "fn f(seed: u64) { let r = Rng::new(seed ^ SALT_ENGINE); }\n";
    assert!(lint_source("src/simulator/x.rs", named).is_clean());
    assert!(lint_source("src/simulator/x.rs", "fn f() { let r = Rng::new(42); }\n").is_clean());
    let hashed = "fn f(seed: u64) { let r = Rng::new(seed ^ fnv1a(b\"tag\")); }\n";
    assert!(lint_source("src/experiments/x.rs", hashed).is_clean());
}

#[test]
fn d003_flags_entropy_sources_even_in_tests() {
    // a hash-seeded or entropy-fed test is nondeterministic CI — no exemption
    let src = "#[cfg(test)]\nmod tests {\n    fn f() { let r = thread_rng(); }\n}\n";
    let out = lint_source("src/util/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D003"]);
    for ident in ["DefaultHasher", "RandomState", "from_entropy"] {
        let src = format!("fn f() {{ let h = {ident}::default(); }}\n");
        assert_eq!(rules_of(&lint_source("src/util/x.rs", &src)), vec!["D003"], "{ident}");
    }
}

// ---------------------------------------------------------------- D004

#[test]
fn d004_flags_partial_cmp_everywhere() {
    let src = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
    for path in ["src/util/x.rs", "src/simulator/x.rs", "tests/test_x.rs"] {
        assert_eq!(rules_of(&lint_source(path, src)), vec!["D004"], "{path}");
    }
    let total = "fn f(xs: &mut [f64]) { xs.sort_by(f64::total_cmp); }\n";
    assert!(lint_source("src/simulator/x.rs", total).is_clean());
}

#[test]
fn d004_flags_exact_float_compares_in_scoped_paths_only() {
    let src = "fn f(x: f64) -> bool { x == 1.0 }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/x.rs", src)), vec!["D004"]);
    let neq = "fn f(x: f64) -> bool { 0.5 != x }\n";
    assert_eq!(rules_of(&lint_source("src/learner/x.rs", neq)), vec!["D004"]);
    // unscoped path, integer compare, and test regions all pass
    assert!(lint_source("src/util/x.rs", src).is_clean());
    assert!(lint_source("src/simulator/x.rs", "fn f(x: u64) -> bool { x == 1 }\n").is_clean());
    let test_only = "#[cfg(test)]\nmod tests {\n    fn f(x: f64) -> bool { x == 1.0 }\n}\n";
    assert!(lint_source("src/simulator/x.rs", test_only).is_clean());
}

// ---------------------------------------------------------------- D005

#[test]
fn d005_flags_fallible_pops_in_simulator() {
    let src = "fn f(h: &mut BinaryHeap<u64>) { let e = h.pop().unwrap(); }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/engine.rs", src)), vec!["D005"]);
    let exp = "fn f(w: &mut W) { let e = w.pop_admission().expect(\"q\"); }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/worker.rs", exp)), vec!["D005"]);
}

#[test]
fn d005_accepts_explicit_handling_and_other_paths() {
    let ok = "fn f(h: &mut BinaryHeap<u64>) { while let Some(e) = h.pop() {} }\n";
    assert!(lint_source("src/simulator/engine.rs", ok).is_clean());
    // outside simulator/ the rule does not apply at all
    let src = "fn f(h: &mut BinaryHeap<u64>) { let e = h.pop().unwrap(); }\n";
    assert!(lint_source("src/coordinator/x.rs", src).is_clean());
    let test_only =
        "#[cfg(test)]\nmod tests {\n    fn f(h: &mut H) { let e = h.pop().unwrap(); }\n}\n";
    assert!(lint_source("src/simulator/x.rs", test_only).is_clean());
}

// ------------------------------------------------------ lint:allow escapes

#[test]
fn allow_trailing_comment_covers_its_line() {
    let src = "use std::collections::HashMap; // lint:allow(D001): fixture reason\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean(), "{:?}", out.violations);
    assert_eq!(out.allowed.len(), 1);
    assert_eq!(out.allowed[0].rule, "D001");
    assert_eq!(out.allowed[0].reason, "fixture reason");
    assert!(out.unused_allows.is_empty());
}

#[test]
fn allow_standalone_comment_covers_next_code_line() {
    let src = "// lint:allow(D002): fixture reason\nfn f() { let t = Instant::now(); }\n";
    let out = lint_source("src/metrics/x.rs", src);
    assert!(out.is_clean(), "{:?}", out.violations);
    assert_eq!(out.allowed.len(), 1);
}

#[test]
fn allow_comma_list_covers_multiple_rules() {
    let src = "// lint:allow(D001,D004): fixture reason\n\
               fn f(m: &HashMap<u32, f64>, x: f64) -> bool { x == 1.0 }\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean(), "{:?}", out.violations);
    assert_eq!(out.allowed.len(), 2);
}

#[test]
fn allow_does_not_leak_to_other_rules_or_lines() {
    // the escape names D001; the D004 hit on the same line still fires
    let src = "// lint:allow(D001): fixture reason\n\
               fn f(m: &HashMap<u32, f64>, x: f64) -> bool { x == 1.0 }\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D004"]);
    // ... and an escape two lines up covers nothing but the next code line
    let far = "// lint:allow(D001): fixture reason\nfn g() {}\nuse std::collections::HashMap;\n";
    let out = lint_source("src/simulator/x.rs", far);
    assert_eq!(rules_of(&out), vec!["D001"]);
    assert_eq!(out.unused_allows.len(), 1);
}

#[test]
fn allow_without_reason_is_itself_a_violation() {
    let src = "use std::collections::HashMap; // lint:allow(D001)\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
    assert!(out.violations[0].message.contains("reason"), "{}", out.violations[0].message);
}

#[test]
fn unused_allow_is_reported_but_not_fatal() {
    let src = "// lint:allow(D005): stale escape\nfn f() {}\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean());
    assert_eq!(out.unused_allows.len(), 1);
    assert_eq!(out.unused_allows[0].rule, "D005");
}

#[test]
fn doc_comments_never_carry_escapes() {
    // documentation *about* the syntax must not register as an escape
    let src = "/// Use `lint:allow(D001): reason` to escape.\nfn f() {}\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean());
    assert!(out.unused_allows.is_empty());
}

// ------------------------------------------------------------ reporting

#[test]
fn report_renders_violations_and_allow_table() {
    let src = "use std::collections::HashMap;\n\
               use std::collections::BTreeMap; // lint:allow(D004): fixture reason, unused\n";
    let mut out = lint_source("src/simulator/x.rs", src);
    let text = report::render(&out);
    assert!(text.contains("D001"), "{text}");
    assert!(text.contains("src/simulator/x.rs:1"), "{text}");
    assert!(text.contains("unused lint:allow"), "{text}");
    // json carries the same facts plus the verdict bit
    let json = report::to_json(&out).to_string();
    assert!(json.contains("\"clean\":false"), "{json}");
    assert!(json.contains("\"rule\":\"D001\""), "{json}");
    out.violations.clear();
    assert!(report::to_json(&out).to_string().contains("\"clean\":true"));
}

#[test]
fn json_report_is_deterministic() {
    let src = "use std::collections::HashMap;\nfn f(x: f64) -> bool { x == 1.0 }\n";
    let a = report::to_json(&lint_source("src/learner/x.rs", src)).to_pretty();
    let b = report::to_json(&lint_source("src/learner/x.rs", src)).to_pretty();
    assert_eq!(a, b);
}

// ------------------------------------------------------------ self-check

#[test]
fn repo_tree_is_lint_clean() {
    // cargo runs integration tests with cwd = the crate dir (`rust/`);
    // `lint_tree` also accepts the workspace root, which is what the CI
    // step and `make lint` pass.
    let out = lint_tree(Path::new(".")).expect("tree walk");
    assert!(out.files > 50, "expected the whole crate, saw {} files", out.files);
    assert!(
        out.is_clean(),
        "repo tree must be lint-clean:\n{}",
        report::render(&out)
    );
    // every escape in the tree carries its reason (the acceptance bar:
    // no blanket, unexplained suppressions anywhere)
    assert!(!out.allowed.is_empty(), "the audited sites should be visible");
    for a in &out.allowed {
        assert!(!a.reason.is_empty(), "allow without reason at {}:{}", a.path, a.line);
    }
    assert!(out.unused_allows.is_empty(), "stale escapes: {:?}", out.unused_allows);
}
