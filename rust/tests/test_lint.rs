//! Determinism-linter battery (DESIGN.md §Static analysis).
//!
//! Per-rule positive/negative fixtures as embedded strings (no temp-file
//! nondeterminism) for the token rules D001–D005 and the cross-file
//! rules D006–D010 (including two-file fixtures proving cross-file
//! resolution), the `lint:allow` / `lint:covers` / `lint:reducer`
//! escape semantics, mutation self-checks over the real sources (delete
//! an aggregated field, collide two salts, add an orphan trace variant —
//! each must fail with a two-location diagnostic), and the self-check
//! that the repo tree itself is lint-clean — which is exactly what the
//! CI gate (`cargo run --release -- lint`) enforces.

use std::collections::BTreeSet;
use std::path::Path;

use shabari::analysis::{
    lint_source, lint_sources, lint_sources_only, lint_tree, report, rules, tree_files,
    LintOutcome,
};

/// Rules fired on a fixture, in report order.
fn rules_of(out: &LintOutcome) -> Vec<&str> {
    out.violations.iter().map(|v| v.rule.as_str()).collect()
}

// ---------------------------------------------------------------- D001

#[test]
fn d001_flags_hash_collections_in_scoped_paths() {
    let src = "use std::collections::HashMap;\n";
    for dir in ["simulator", "coordinator", "learner", "metrics"] {
        let out = lint_source(&format!("src/{dir}/x.rs"), src);
        assert_eq!(rules_of(&out), vec!["D001"], "{dir}");
    }
    let out = lint_source("src/simulator/x.rs", "fn f(s: &mut HashSet<u32>) {}\n");
    assert_eq!(rules_of(&out), vec!["D001"]);
}

#[test]
fn d001_ignores_unscoped_paths_and_sorted_collections() {
    let src = "use std::collections::HashMap;\n";
    assert!(lint_source("src/util/x.rs", src).is_clean());
    assert!(lint_source("tests/test_x.rs", src).is_clean());
    let sorted = "use std::collections::{BTreeMap, BTreeSet};\n";
    assert!(lint_source("src/simulator/x.rs", sorted).is_clean());
}

#[test]
fn d001_exempts_test_regions_and_string_literals() {
    let test_only = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
    assert!(lint_source("src/simulator/x.rs", test_only).is_clean());
    let in_str = "const MSG: &str = \"HashMap order leaks\";\n";
    assert!(lint_source("src/simulator/x.rs", in_str).is_clean());
}

// ---------------------------------------------------------------- D002

#[test]
fn d002_flags_wall_clock_reads() {
    let src = "fn f() { let t0 = std::time::Instant::now(); }\n";
    let out = lint_source("src/metrics/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D002"]);
    let sys = "fn f() { let t = std::time::SystemTime::now(); }\n";
    assert_eq!(rules_of(&lint_source("src/util/x.rs", sys)), vec!["D002"]);
}

#[test]
fn d002_exempts_bench_paths_tests_and_bare_imports() {
    let src = "fn f() { let t0 = std::time::Instant::now(); }\n";
    assert!(lint_source("src/util/bench.rs", src).is_clean());
    assert!(lint_source("benches/bench_x.rs", src).is_clean());
    let test_only = format!("#[cfg(test)]\nmod tests {{\n    {src}\n}}\n");
    assert!(lint_source("src/simulator/x.rs", &test_only).is_clean());
    // importing the type is fine; only the `::now` read is a violation
    assert!(lint_source("src/simulator/x.rs", "use std::time::Instant;\n").is_clean());
}

// ---------------------------------------------------------------- D003

#[test]
fn d003_flags_inline_rng_salts() {
    let src = "fn f(seed: u64) { let r = Rng::new(seed ^ 0x5115_BA71); }\n";
    let out = lint_source("src/workload/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D003"]);
    // literal-first order is the same violation
    let flipped = "fn f(seed: u64) { let r = Rng::new(0xABC ^ seed); }\n";
    assert_eq!(rules_of(&lint_source("src/workload/x.rs", flipped)), vec!["D003"]);
}

#[test]
fn d003_accepts_named_salts_plain_seeds_and_hashes() {
    // the const is defined in-fixture so the D006 registry resolves it
    let named = "const SALT_ENGINE: u64 = 0x5115_BA71;\n\
                 fn f(seed: u64) { let r = Rng::new(seed ^ SALT_ENGINE); }\n";
    assert!(lint_source("src/simulator/x.rs", named).is_clean());
    assert!(lint_source("src/simulator/x.rs", "fn f() { let r = Rng::new(42); }\n").is_clean());
    let hashed = "fn f(seed: u64) { let r = Rng::new(seed ^ fnv1a(b\"tag\")); }\n";
    assert!(lint_source("src/experiments/x.rs", hashed).is_clean());
}

#[test]
fn d003_flags_entropy_sources_even_in_tests() {
    // a hash-seeded or entropy-fed test is nondeterministic CI — no exemption
    let src = "#[cfg(test)]\nmod tests {\n    fn f() { let r = thread_rng(); }\n}\n";
    let out = lint_source("src/util/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D003"]);
    for ident in ["DefaultHasher", "RandomState", "from_entropy"] {
        let src = format!("fn f() {{ let h = {ident}::default(); }}\n");
        assert_eq!(rules_of(&lint_source("src/util/x.rs", &src)), vec!["D003"], "{ident}");
    }
}

// ---------------------------------------------------------------- D004

#[test]
fn d004_flags_partial_cmp_everywhere() {
    let src = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
    for path in ["src/util/x.rs", "src/simulator/x.rs", "tests/test_x.rs"] {
        assert_eq!(rules_of(&lint_source(path, src)), vec!["D004"], "{path}");
    }
    let total = "fn f(xs: &mut [f64]) { xs.sort_by(f64::total_cmp); }\n";
    assert!(lint_source("src/simulator/x.rs", total).is_clean());
}

#[test]
fn d004_flags_exact_float_compares_in_scoped_paths_only() {
    let src = "fn f(x: f64) -> bool { x == 1.0 }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/x.rs", src)), vec!["D004"]);
    let neq = "fn f(x: f64) -> bool { 0.5 != x }\n";
    assert_eq!(rules_of(&lint_source("src/learner/x.rs", neq)), vec!["D004"]);
    // unscoped path, integer compare, and test regions all pass
    assert!(lint_source("src/util/x.rs", src).is_clean());
    assert!(lint_source("src/simulator/x.rs", "fn f(x: u64) -> bool { x == 1 }\n").is_clean());
    let test_only = "#[cfg(test)]\nmod tests {\n    fn f(x: f64) -> bool { x == 1.0 }\n}\n";
    assert!(lint_source("src/simulator/x.rs", test_only).is_clean());
}

// ---------------------------------------------------------------- D005

#[test]
fn d005_flags_fallible_pops_in_simulator() {
    let src = "fn f(h: &mut BinaryHeap<u64>) { let e = h.pop().unwrap(); }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/engine.rs", src)), vec!["D005"]);
    let exp = "fn f(w: &mut W) { let e = w.pop_admission().expect(\"q\"); }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/worker.rs", exp)), vec!["D005"]);
}

#[test]
fn d005_accepts_explicit_handling_and_other_paths() {
    let ok = "fn f(h: &mut BinaryHeap<u64>) { while let Some(e) = h.pop() {} }\n";
    assert!(lint_source("src/simulator/engine.rs", ok).is_clean());
    // outside simulator/ the rule does not apply at all
    let src = "fn f(h: &mut BinaryHeap<u64>) { let e = h.pop().unwrap(); }\n";
    assert!(lint_source("src/coordinator/x.rs", src).is_clean());
    let test_only =
        "#[cfg(test)]\nmod tests {\n    fn f(h: &mut H) { let e = h.pop().unwrap(); }\n}\n";
    assert!(lint_source("src/simulator/x.rs", test_only).is_clean());
}

// ------------------------------------------------------ lint:allow escapes

#[test]
fn allow_trailing_comment_covers_its_line() {
    let src = "use std::collections::HashMap; // lint:allow(D001): fixture reason\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean(), "{:?}", out.violations);
    assert_eq!(out.allowed.len(), 1);
    assert_eq!(out.allowed[0].rule, "D001");
    assert_eq!(out.allowed[0].reason, "fixture reason");
    assert!(out.unused_allows.is_empty());
}

#[test]
fn allow_standalone_comment_covers_next_code_line() {
    let src = "// lint:allow(D002): fixture reason\nfn f() { let t = Instant::now(); }\n";
    let out = lint_source("src/metrics/x.rs", src);
    assert!(out.is_clean(), "{:?}", out.violations);
    assert_eq!(out.allowed.len(), 1);
}

#[test]
fn allow_comma_list_covers_multiple_rules() {
    let src = "// lint:allow(D001,D004): fixture reason\n\
               fn f(m: &HashMap<u32, f64>, x: f64) -> bool { x == 1.0 }\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean(), "{:?}", out.violations);
    assert_eq!(out.allowed.len(), 2);
}

#[test]
fn allow_does_not_leak_to_other_rules_or_lines() {
    // the escape names D001; the D004 hit on the same line still fires
    let src = "// lint:allow(D001): fixture reason\n\
               fn f(m: &HashMap<u32, f64>, x: f64) -> bool { x == 1.0 }\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D004"]);
    // ... and an escape two lines up covers nothing but the next code line
    let far = "// lint:allow(D001): fixture reason\nfn g() {}\nuse std::collections::HashMap;\n";
    let out = lint_source("src/simulator/x.rs", far);
    assert_eq!(rules_of(&out), vec!["D001"]);
    assert_eq!(out.unused_allows.len(), 1);
}

#[test]
fn allow_without_reason_is_itself_a_violation() {
    let src = "use std::collections::HashMap; // lint:allow(D001)\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
    assert!(out.violations[0].message.contains("reason"), "{}", out.violations[0].message);
}

#[test]
fn unused_allow_is_reported_but_not_fatal() {
    let src = "// lint:allow(D005): stale escape\nfn f() {}\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean());
    assert_eq!(out.unused_allows.len(), 1);
    assert_eq!(out.unused_allows[0].rule, "D005");
}

#[test]
fn doc_comments_never_carry_escapes() {
    // documentation *about* the syntax must not register as an escape
    let src = "/// Use `lint:allow(D001): reason` to escape.\nfn f() {}\n";
    let out = lint_source("src/simulator/x.rs", src);
    assert!(out.is_clean());
    assert!(out.unused_allows.is_empty());
}

// ------------------------------------------------------------ reporting

#[test]
fn report_renders_violations_and_allow_table() {
    let src = "use std::collections::HashMap;\n\
               use std::collections::BTreeMap; // lint:allow(D004): fixture reason, unused\n";
    let mut out = lint_source("src/simulator/x.rs", src);
    let text = report::render(&out);
    assert!(text.contains("D001"), "{text}");
    assert!(text.contains("src/simulator/x.rs:1"), "{text}");
    assert!(text.contains("unused lint:allow"), "{text}");
    // json carries the same facts plus the verdict bit
    let json = report::to_json(&out).to_string();
    assert!(json.contains("\"clean\":false"), "{json}");
    assert!(json.contains("\"rule\":\"D001\""), "{json}");
    out.violations.clear();
    assert!(report::to_json(&out).to_string().contains("\"clean\":true"));
}

#[test]
fn json_report_is_deterministic() {
    let src = "use std::collections::HashMap;\nfn f(x: f64) -> bool { x == 1.0 }\n";
    let a = report::to_json(&lint_source("src/learner/x.rs", src)).to_pretty();
    let b = report::to_json(&lint_source("src/learner/x.rs", src)).to_pretty();
    assert_eq!(a, b);
}

// ---------------------------------------------- D006: salt registry

#[test]
fn d006_flags_duplicate_salt_names_with_both_sites() {
    let a = ("src/simulator/a.rs", "pub const SALT_X: u64 = 0x1;\n");
    let b = ("src/simulator/b.rs", "pub const SALT_X: u64 = 0x2;\n");
    let out = lint_sources(&[a, b]);
    assert_eq!(rules_of(&out), vec!["D006"]);
    let v = &out.violations[0];
    assert_eq!(v.path, "src/simulator/b.rs");
    let r = v.related.as_ref().expect("duplicate must cite the first definition");
    assert_eq!((r.path.as_str(), r.line), ("src/simulator/a.rs", 1));
}

#[test]
fn d006_flags_value_collisions_across_files() {
    // two distinct names, one literal value: streams would correlate
    let a = ("src/simulator/a.rs", "pub const SALT_A: u64 = 0xBEEF;\n");
    let b = ("src/simulator/b.rs", "pub const SALT_B: u64 = 0xBEEF;\n");
    let out = lint_sources(&[a, b]);
    assert_eq!(rules_of(&out), vec!["D006"]);
    assert!(out.violations[0].related.is_some(), "{:?}", out.violations);
    // distinct values are the contract
    let a = ("src/simulator/a.rs", "pub const SALT_A: u64 = 0x1;\n");
    let b = ("src/simulator/b.rs", "pub const SALT_B: u64 = 0x2;\n");
    assert!(lint_sources(&[a, b]).is_clean());
}

#[test]
fn d006_resolves_salt_uses_across_files() {
    // definition in one file, fork in another: the crate pass must join them
    let def = ("src/util/salts.rs", "pub const SALT_W: u64 = 0x3;\n");
    let fork = ("src/workload/x.rs", "fn f(seed: u64) { let r = Rng::new(seed ^ SALT_W); }\n");
    assert!(lint_sources(&[def, fork]).is_clean());
    // without the defining file, the operand is unresolved
    let out = lint_sources(&[fork]);
    assert_eq!(rules_of(&out), vec!["D006"]);
    assert!(out.violations[0].message.contains("SALT_W"), "{:?}", out.violations);
}

// ---------------------------------- D007: metrics-aggregation coverage

const METRICS_FIXTURE: &str = "pub struct RunMetrics {\n\
                               \x20   pub policy: String,\n\
                               \x20   pub a_pct: f64,\n\
                               \x20   pub peak: f64,\n\
                               }\n\
                               impl RunMetrics {\n\
                               \x20   pub fn mean_of(runs: &[RunMetrics]) -> RunMetrics {\n\
                               \x20       let a = runs.iter().map(|r| r.a_pct).sum::<f64>();\n\
                               \x20       unimplemented!()\n\
                               \x20   }\n\
                               }\n";

#[test]
fn d007_flags_numeric_fields_missing_from_mean_of() {
    let out = lint_source("src/metrics/mod.rs", METRICS_FIXTURE);
    assert_eq!(rules_of(&out), vec!["D007"]);
    let v = &out.violations[0];
    assert_eq!(v.line, 4, "anchored at the field definition");
    assert!(v.message.contains("peak"), "{}", v.message);
    let r = v.related.as_ref().expect("must cite mean_of");
    assert_eq!(r.line, 7);
    // non-numeric fields (policy: String) are exempt, a_pct is referenced
}

#[test]
fn d007_reducer_annotation_covers_max_reduced_fields() {
    let src = format!("// lint:reducer(D007, peak): max-reduced fixture\n{METRICS_FIXTURE}");
    assert!(lint_source("src/metrics/mod.rs", &src).is_clean());
}

#[test]
fn d007_reducer_naming_an_unknown_field_is_a_violation() {
    let src = format!("// lint:reducer(D007, nope): stale name\n{METRICS_FIXTURE}");
    let out = lint_source("src/metrics/mod.rs", &src);
    // the stale directive AND the still-uncovered field both fire
    assert_eq!(rules_of(&out), vec!["D007", "D007"]);
    assert!(out.violations.iter().any(|v| v.message.contains("nope")), "{:?}", out.violations);
}

#[test]
fn d007_is_anchored_to_the_metrics_module_root() {
    // the same shape elsewhere is not the aggregation contract
    assert!(lint_source("src/metrics/histogram.rs", METRICS_FIXTURE).is_clean());
}

// ------------------------------------ D008: trace-taxonomy coverage

const TRACE_FIXTURE: &str = "pub struct TraceEvent { pub kind: TraceEventKind }\n\
    pub enum TraceEventKind {\n\
    \x20   Arrival { inv: u64 },\n\
    \x20   Stray { worker: usize },\n\
    }\n\
    pub fn assemble_spans(log: &TraceLog) -> Vec<Span> {\n\
    \x20   match kind {\n\
    \x20       TraceEventKind::Arrival { inv } => push(inv),\n\
    \x20       // lint:covers(D008, Stray): fixture: worker events carry no invocation id\n\
    \x20       _ => {}\n\
    \x20   }\n\
    }\n\
    impl TraceEvent {\n\
    \x20   pub fn to_json(&self) -> String {\n\
    \x20       match &self.kind {\n\
    \x20           TraceEventKind::Arrival { inv } => fmt(inv),\n\
    \x20           TraceEventKind::Stray { worker } => fmt(worker),\n\
    \x20       }\n\
    \x20   }\n\
    }\n\
    impl TraceLog {\n\
    \x20   pub fn to_chrome(&self) -> String {\n\
    \x20       match &self.kind {\n\
    \x20           TraceEventKind::Arrival { inv } => fmt(inv),\n\
    \x20           TraceEventKind::Stray { worker } => fmt(worker),\n\
    \x20       }\n\
    \x20   }\n\
    }\n";

#[test]
fn d008_accepts_handlers_that_cover_or_annotate_every_variant() {
    assert!(lint_source("src/simulator/trace.rs", TRACE_FIXTURE).is_clean());
}

#[test]
fn d008_flags_a_variant_a_handler_drops() {
    // strip the covers annotation: assemble_spans no longer accounts for Stray
    let src = TRACE_FIXTURE.replace(
        "// lint:covers(D008, Stray): fixture: worker events carry no invocation id\n",
        "",
    );
    let out = lint_source("src/simulator/trace.rs", &src);
    assert_eq!(rules_of(&out), vec!["D008"]);
    let v = &out.violations[0];
    assert!(v.message.contains("Stray"), "{}", v.message);
    assert!(v.message.contains("span assembly"), "{}", v.message);
    assert!(v.related.is_some(), "must cite the handler");
}

#[test]
fn d008_flags_variants_never_constructed_in_the_simulator() {
    // a second simulator file turns the construction check on; it only
    // builds Arrival, so Stray is dead taxonomy
    let engine = ("src/simulator/engine.rs", "fn emit() { t(TraceEventKind::Arrival { inv: 1 }); }\n");
    let out = lint_sources(&[("src/simulator/trace.rs", TRACE_FIXTURE), engine]);
    assert_eq!(rules_of(&out), vec!["D008"]);
    assert!(out.violations[0].message.contains("Stray"), "{:?}", out.violations);
    assert!(out.violations[0].message.contains("constructed"), "{:?}", out.violations);
    // patterns don't count as construction; real constructions do
    let engine_ok = (
        "src/simulator/engine.rs",
        "fn emit() { t(TraceEventKind::Arrival { inv: 1 }); t(TraceEventKind::Stray { worker: 0 }); }\n",
    );
    assert!(lint_sources(&[("src/simulator/trace.rs", TRACE_FIXTURE), engine_ok]).is_clean());
}

#[test]
fn d008_covers_naming_an_unknown_variant_is_a_violation() {
    let src = TRACE_FIXTURE.replace(
        "lint:covers(D008, Stray): fixture",
        "lint:covers(D008, Stray, Gone): fixture",
    );
    let out = lint_source("src/simulator/trace.rs", &src);
    assert_eq!(rules_of(&out), vec!["D008"]);
    assert!(out.violations[0].message.contains("Gone"), "{:?}", out.violations);
}

// ---------------------------------------- D009: eviction funnel

const ENGINE_FIXTURE_OK: &str = "impl Engine {\n\
    \x20   fn schedule_idle_evict(&mut self) {\n\
    \x20       self.push(t, EventKind::Evict { worker, container, idle_epoch });\n\
    \x20   }\n\
    \x20   fn handle(&mut self, e: EventKind) {\n\
    \x20       match e { EventKind::Evict { worker, .. } => drain(worker), _ => {} }\n\
    \x20   }\n\
    }\n";

#[test]
fn d009_accepts_construction_inside_the_funnel_and_match_arms_anywhere() {
    assert!(lint_source("src/simulator/engine.rs", ENGINE_FIXTURE_OK).is_clean());
}

#[test]
fn d009_flags_evict_pushed_outside_the_funnel() {
    let src = format!(
        "{ENGINE_FIXTURE_OK}\
         impl Rogue {{\n\
         \x20   fn sneak(&mut self) {{\n\
         \x20       self.push(t, EventKind::Evict {{ worker, container, idle_epoch }});\n\
         \x20   }}\n\
         }}\n"
    );
    let out = lint_source("src/simulator/engine.rs", &src);
    assert_eq!(rules_of(&out), vec!["D009"]);
    let v = &out.violations[0];
    assert_eq!(v.line, 11, "the rogue push site");
    let r = v.related.as_ref().expect("must cite the sanctioned site");
    assert_eq!(r.line, 2, "schedule_idle_evict");
}

// ---------------------------------------- D010: RNG-stream hygiene

#[test]
fn d010_flags_rng_clones() {
    let src = "fn f(rng: &Rng) { let r2 = rng.clone(); }\n";
    let out = lint_source("src/workload/x.rs", src);
    assert_eq!(rules_of(&out), vec!["D010"]);
}

#[test]
fn d010_flags_two_forks_sharing_a_salt_across_files() {
    let a = (
        "src/simulator/a.rs",
        "pub const SALT_S: u64 = 1;\nfn f(s: u64) { let r = Rng::new(s ^ SALT_S); }\n",
    );
    let b = ("src/simulator/b.rs", "fn g(s: u64) { let r = Rng::new(s ^ SALT_S); }\n");
    let out = lint_sources(&[a, b]);
    assert_eq!(rules_of(&out), vec!["D010"]);
    let v = &out.violations[0];
    assert_eq!(v.path, "src/simulator/b.rs");
    let r = v.related.as_ref().expect("must cite the first fork");
    assert_eq!((r.path.as_str(), r.line), ("src/simulator/a.rs", 2));
    // distinct salts per fork are the contract
    let b_ok = (
        "src/simulator/b.rs",
        "pub const SALT_T: u64 = 2;\nfn g(s: u64) { let r = Rng::new(s ^ SALT_T); }\n",
    );
    assert!(lint_sources(&[a, b_ok]).is_clean());
}

// -------------------------------------- directive hygiene & filtering

#[test]
fn directives_without_reasons_or_with_wrong_rules_are_violations() {
    let bare = "// lint:reducer(D007, peak)\nfn f() {}\n";
    let out = lint_source("src/metrics/x.rs", bare);
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
    assert!(out.violations[0].message.contains("reason"), "{}", out.violations[0].message);
    // covers belongs to D008, reducer to D007 — crossed verbs are errors
    let crossed = "// lint:covers(D007, peak): wrong rule\nfn f() {}\n";
    let out = lint_source("src/metrics/x.rs", crossed);
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
    assert!(out.violations[0].message.contains("D008"), "{}", out.violations[0].message);
}

#[test]
fn only_filter_restricts_rules_but_not_escape_hygiene() {
    let a = (
        "src/simulator/a.rs",
        "use std::collections::HashMap;\npub const SALT_X: u64 = 1;\n",
    );
    let b = ("src/simulator/b.rs", "pub const SALT_X: u64 = 2;\n");
    // unfiltered: the token rule and the crate rule both fire
    assert_eq!(rules_of(&lint_sources(&[a, b])), vec!["D001", "D006"]);
    let only: BTreeSet<String> = std::iter::once("D006".to_string()).collect();
    assert_eq!(rules_of(&lint_sources_only(&[a, b], Some(&only))), vec!["D006"]);
    // a reasonless escape still fires even when its rule is filtered out
    let c = ("src/simulator/c.rs", "use std::collections::HashMap; // lint:allow(D001)\n");
    let out = lint_sources_only(&[c], Some(&only));
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
    assert!(out.violations[0].message.contains("reason"), "{}", out.violations[0].message);
}

// ------------------------------------------- registry & walk coverage

#[test]
fn rule_registry_lists_all_ten_rules_with_pass_labels() {
    let metas = rules::rule_metas();
    let ids: Vec<&str> = metas.iter().map(|m| m.id).collect();
    assert_eq!(
        ids,
        ["D001", "D002", "D003", "D004", "D005", "D006", "D007", "D008", "D009", "D010"]
    );
    let listing = report::render_rule_list();
    for id in ids {
        assert!(listing.contains(id), "{listing}");
    }
    assert!(listing.contains("token"), "{listing}");
    assert!(listing.contains("crate"), "{listing}");
}

#[test]
fn json_report_carries_pass_scope_and_related_sites() {
    let a = ("src/simulator/a.rs", "pub const SALT_X: u64 = 1;\n");
    let b = ("src/simulator/b.rs", "pub const SALT_X: u64 = 2;\n");
    let json = report::to_json(&lint_sources(&[a, b])).to_string();
    assert!(json.contains("\"pass\":\"crate\""), "{json}");
    assert!(json.contains("\"scope\":"), "{json}");
    assert!(json.contains("\"related\":"), "{json}");
    assert!(json.contains("src/simulator/a.rs"), "{json}");
}

#[test]
fn tree_walk_covers_tests_benches_and_examples() {
    let files = tree_files(Path::new(".")).expect("walk");
    let labels: Vec<&str> = files.iter().map(|(l, _)| l.as_str()).collect();
    assert!(labels.iter().any(|l| l.starts_with("src/")), "{labels:?}");
    assert!(labels.contains(&"tests/test_lint.rs"), "{labels:?}");
    assert!(labels.iter().any(|l| l.starts_with("benches/")), "{labels:?}");
    assert!(labels.contains(&"examples/serve_trace.rs"), "{labels:?}");
}

// ------------------------------------- mutation self-checks (real tree)

/// Integration tests run with cwd = the crate dir (`rust/`); keep the
/// repo-root fallback so the battery also runs from the workspace root.
fn read_src(rel: &str) -> String {
    std::fs::read_to_string(rel)
        .or_else(|_| std::fs::read_to_string(format!("rust/{rel}")))
        .unwrap_or_else(|e| panic!("reading {rel}: {e}"))
}

#[test]
fn deleting_a_field_from_mean_of_fails_with_two_locations() {
    let metrics = read_src("src/metrics/mod.rs");
    assert!(lint_source("src/metrics/mod.rs", &metrics).is_clean(), "baseline must be clean");
    let cut = metrics.replace("oom_pct: avg(|r| r.oom_pct),", "");
    assert_ne!(cut, metrics, "the aggregation line must exist to be deleted");
    let out = lint_source("src/metrics/mod.rs", &cut);
    let v = out
        .violations
        .iter()
        .find(|v| v.rule == "D007")
        .unwrap_or_else(|| panic!("dropped field must trip D007: {:?}", out.violations));
    assert!(v.message.contains("oom_pct"), "{}", v.message);
    assert!(v.related.is_some(), "must cite mean_of as the second location");
}

#[test]
fn duplicating_a_salt_value_fails_with_two_locations() {
    let engine = read_src("src/simulator/engine.rs");
    let faults = read_src("src/simulator/faults/mod.rs");
    let files = |e: &str, f: &str| {
        lint_sources(&[
            ("src/simulator/engine.rs", e),
            ("src/simulator/faults/mod.rs", f),
        ])
    };
    assert!(files(&engine, &faults).is_clean(), "baseline must be clean");
    // give SALT_ENGINE the literal value of SALT_CRASH
    let collided = engine.replace("0x5115_BA71", "0xC4A5_4ED1");
    assert_ne!(collided, engine);
    let out = files(&collided, &faults);
    let v = out
        .violations
        .iter()
        .find(|v| v.rule == "D006")
        .unwrap_or_else(|| panic!("colliding salts must trip D006: {:?}", out.violations));
    assert!(v.related.is_some(), "must cite the other definition");
}

#[test]
fn scaler_salt_is_registered_and_its_fork_is_covered() {
    // SALT_SCALER is part of the D006 registry: colliding its value with
    // SALT_ENGINE must fire with both definition sites cited.
    let engine = read_src("src/simulator/engine.rs");
    let scaler = read_src("src/simulator/scaler/mod.rs");
    let files = |e: &str, s: &str| {
        lint_sources(&[
            ("src/simulator/engine.rs", e),
            ("src/simulator/scaler/mod.rs", s),
        ])
    };
    assert!(files(&engine, &scaler).is_clean(), "baseline must be clean");
    assert!(scaler.contains("0x5CA1_E550"), "SALT_SCALER value moved; update this test");
    let collided = scaler.replace("0x5CA1_E550", "0x5115_BA71");
    assert_ne!(collided, scaler);
    let out = files(&engine, &collided);
    let v = out
        .violations
        .iter()
        .find(|v| v.rule == "D006")
        .unwrap_or_else(|| panic!("colliding SALT_SCALER must trip D006: {:?}", out.violations));
    assert!(v.related.is_some(), "must cite the other definition");
    // D003 covers the scaler module like everything else: an
    // inline-literal fork there is flagged
    let inline = "fn f(seed: u64) { let r = Rng::new(seed ^ 0x5CA1_E550); }\n";
    assert_eq!(rules_of(&lint_source("src/simulator/scaler/x.rs", inline)), vec!["D003"]);
    // D010: a second fork off SALT_SCALER anywhere in the crate is one
    // stream under two names
    let second =
        ("src/simulator/x.rs", "fn g(s: u64) { let r = Rng::new(s ^ SALT_SCALER); }\n");
    let out = lint_sources(&[("src/simulator/scaler/mod.rs", scaler.as_str()), second]);
    assert_eq!(rules_of(&out), vec!["D010"]);
}

#[test]
fn adding_an_unhandled_trace_variant_fails_with_two_locations() {
    let trace = read_src("src/simulator/trace.rs");
    let engine = read_src("src/simulator/engine.rs");
    let files = |t: &str, e: &str| {
        lint_sources(&[
            ("src/simulator/trace.rs", t),
            ("src/simulator/engine.rs", e),
        ])
    };
    assert!(files(&trace, &engine).is_clean(), "baseline must be clean");
    let grown = trace.replace(
        "WorkerRestart { worker: usize },",
        "WorkerRestart { worker: usize },\n    Zombie { worker: usize },",
    );
    assert_ne!(grown, trace);
    let out = files(&grown, &engine);
    let zombie: Vec<_> =
        out.violations.iter().filter(|v| v.rule == "D008" && v.message.contains("Zombie")).collect();
    // unhandled in all three exporters plus never constructed
    assert!(zombie.len() >= 3, "{:?}", out.violations);
    assert!(
        zombie.iter().any(|v| v.related.is_some()),
        "handler gaps must cite the handler: {:?}",
        out.violations
    );
}

// ------------------------------------------------------------ self-check

#[test]
fn repo_tree_is_lint_clean() {
    // cargo runs integration tests with cwd = the crate dir (`rust/`);
    // `lint_tree` also accepts the workspace root, which is what the CI
    // step and `make lint` pass.
    let out = lint_tree(Path::new(".")).expect("tree walk");
    assert!(out.files > 50, "expected the whole crate, saw {} files", out.files);
    assert!(
        out.is_clean(),
        "repo tree must be lint-clean:\n{}",
        report::render(&out)
    );
    // every escape in the tree carries its reason (the acceptance bar:
    // no blanket, unexplained suppressions anywhere)
    assert!(!out.allowed.is_empty(), "the audited sites should be visible");
    for a in &out.allowed {
        assert!(!a.reason.is_empty(), "allow without reason at {}:{}", a.path, a.line);
    }
    assert!(out.unused_allows.is_empty(), "stale escapes: {:?}", out.unused_allows);
}
