//! Fault-injection battery (ISSUE 6 / DESIGN.md §Faults): end-to-end
//! behavior of crash/restart cycles, stragglers, and heterogeneous
//! worker classes through the public `simulate` entry point.
//!
//! The tests never hunt seeds: [`FaultsSpec::plan`] is horizon-prefix
//! stable and uses RNG streams disjoint from the engine's, so a test can
//! ask the plan for the exact first crash time under the engine's own
//! seed and place arrivals right before it — the crash is then
//! *guaranteed* to land on in-flight work.

use shabari::baselines::StaticPolicy;
use shabari::functions::catalog::{index_of, CATALOG};
use shabari::functions::inputs;
use shabari::simulator::engine::simulate;
use shabari::simulator::faults;
use shabari::simulator::{Request, SimConfig, Verdict};
use shabari::util::rng::Rng;

/// `n` simultaneous qr invocations arriving at `at` (ids from `start_id`).
fn qr_wave(start_id: u64, n: usize, at: f64, slo: f64) -> Vec<Request> {
    let fi = index_of("qr").unwrap();
    let mut rng = Rng::new(17);
    let pool = inputs::pool(&CATALOG[fi], &mut rng);
    (0..n)
        .map(|i| Request {
            id: start_id + i as u64,
            func: fi,
            input: pool[i % pool.len()].clone(),
            arrival: at,
            slo_s: slo,
        })
        .collect()
}

#[test]
fn crash_fails_in_flight_work_and_restart_recovers() {
    // One worker, first crash at t0 (read off the plan), downtime 600 s.
    // A 40-wide wave lands 0.5 s before the crash: with 20-vCPU static
    // asks against a 90-vCPU limit, most of it is still queued or waiting
    // on ~0.55 s cold starts when the worker dies — and with no other
    // worker to reroute to, everything in-system dies as `Failed`. A
    // small wave after the restart must complete normally on the revived
    // worker (the next crash is at least MTBF/2 after the restart).
    let spec = faults::parse("crash:600").unwrap();
    let seed = 123u64;
    let t0 = spec.plan(1, 10_000.0, seed).crashes[0].at;
    let mut reqs = qr_wave(1, 40, t0 - 0.5, 60.0);
    reqs.extend(qr_wave(41, 3, t0 + 605.0, 60.0));
    let mut cfg = SimConfig { workers: 1, seed, ..SimConfig::default() };
    spec.apply(&mut cfg);
    let mut policy = StaticPolicy::large(7);
    let res = simulate(cfg, &mut policy, reqs);

    assert_eq!(res.records.len(), 43, "every arrival must terminate exactly once");
    assert!(res.worker_crashes >= 1, "the planned crash must have fired");
    let failed: Vec<u64> = res
        .records
        .iter()
        .filter(|r| r.verdict == Verdict::Failed)
        .map(|r| r.id)
        .collect();
    assert!(!failed.is_empty(), "a 1-worker crash must strand in-flight work");
    assert!(
        failed.iter().all(|id| *id <= 40),
        "only the pre-crash wave may fail: {failed:?}"
    );
    for r in res.records.iter().filter(|r| r.id > 40) {
        assert_eq!(
            r.verdict,
            Verdict::Completed,
            "restarted worker must serve invocation {} normally",
            r.id
        );
    }
    res.cluster.check_invariants();
}

#[test]
fn crash_requeues_displaced_work_onto_the_surviving_worker() {
    // Two workers, wave 0.5 s before the cluster's first crash. The
    // memory-centric OpenWhisk route (static baselines) spreads 40 x
    // 5 GB asks across both workers' admission queues, so whichever
    // worker dies holds queued/waiting invocations — they must re-enter
    // the admission path on the surviving worker, not vanish.
    let spec = faults::parse("crash:10").unwrap();
    let seed = 77u64;
    let tmin = spec.plan(2, 10_000.0, seed).crashes[0].at;
    let reqs = qr_wave(1, 40, tmin - 0.5, 60.0);
    let mut cfg = SimConfig { workers: 2, seed, ..SimConfig::default() };
    spec.apply(&mut cfg);
    let mut policy = StaticPolicy::large(7);
    let res = simulate(cfg, &mut policy, reqs);

    assert_eq!(res.records.len(), 40, "every arrival must terminate exactly once");
    assert!(res.worker_crashes >= 1);
    assert!(
        res.requeued_on_crash > 0,
        "displaced queued/waiting work must reroute to the up worker"
    );
    res.cluster.check_invariants();
}

#[test]
fn stragglers_stretch_execution_by_the_speed_factor() {
    // A single uncontended invocation on a 0.25x straggler must run ~4x
    // longer than on a nominal worker (the speed factor multiplies into
    // the epoch-cached rate computation; x1.0 is bit-exact).
    let run = |profile: Option<&str>| {
        let mut cfg = SimConfig { workers: 1, seed: 5, ..SimConfig::default() };
        if let Some(p) = profile {
            faults::parse(p).unwrap().apply(&mut cfg);
        }
        let mut policy = StaticPolicy::large(7);
        let res = simulate(cfg, &mut policy, qr_wave(1, 1, 0.0, 60.0));
        assert_eq!(res.records.len(), 1);
        assert_eq!(res.records[0].verdict, Verdict::Completed);
        (res.records[0].exec_s, res.straggler_slowdown)
    };
    let (nominal, s_none) = run(None);
    let (slowed, s_strag) = run(Some("stragglers:0.25"));
    assert_eq!(s_none, 1.0);
    assert_eq!(s_strag, 0.25, "slowdown echoes the configured factor");
    assert!(
        slowed > 2.0 * nominal,
        "0.25x straggler must stretch execution: {nominal}s -> {slowed}s"
    );
}

#[test]
fn hetero_scales_per_worker_limits_and_serves_cleanly() {
    // hetero cycles capacity classes 1.0/0.5/0.25 (worker 0 stays full
    // size); medium 12-vCPU/3 GB asks fit even the quarter worker, so a
    // paced trace completes cleanly and the release-mode invariant check
    // audits each worker against its *own* scaled limits.
    let mut cfg = SimConfig { workers: 3, seed: 9, ..SimConfig::default() };
    faults::parse("hetero").unwrap().apply(&mut cfg);
    let mut reqs = Vec::new();
    for i in 0..12u64 {
        reqs.extend(qr_wave(i + 1, 1, i as f64 * 2.0, 60.0));
    }
    let mut policy = StaticPolicy::medium(7);
    let res = simulate(cfg, &mut policy, reqs);

    let w = &res.cluster.workers;
    assert_eq!(w[0].sched_vcpu_limit, 90.0);
    assert_eq!(w[1].sched_vcpu_limit, 45.0);
    assert_eq!(w[2].sched_vcpu_limit, 22.5);
    assert_eq!(w[0].physical_cores, 96.0);
    assert_eq!(w[1].physical_cores, 48.0);
    assert_eq!(w[2].physical_cores, 24.0);
    assert_eq!(w[0].mem_gb, 125.0);
    assert_eq!(w[1].mem_gb, 62.5);
    assert_eq!(w[2].mem_gb, 31.25);

    assert_eq!(res.records.len(), 12, "every arrival must terminate exactly once");
    assert!(res.records.iter().all(|r| r.verdict == Verdict::Completed));
    assert_eq!(res.worker_crashes, 0, "hetero alone never crashes anyone");
    assert_eq!(res.straggler_slowdown, 1.0, "hetero alone never slows anyone");
    res.cluster.check_invariants();
}
