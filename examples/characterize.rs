//! Measurement-study walkthrough (paper §2): probe the function models
//! the way the paper's ~8K profiling runs probe the real functions —
//! input-size scaling, the videoprocess resolution effect, and bounded
//! parallelism.
//!
//!     cargo run --release --example characterize [--function compress]

use shabari::experiments::{characterize, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::default();
    println!("### §2.1 input properties (Figures 2 & 3)\n");
    characterize::fig2(&ctx)?;
    characterize::fig3(&ctx)?;
    println!("\n### §2.2 function semantics / bounded parallelism (Figure 4)\n");
    characterize::fig4(&ctx)?;
    println!("\n### §2.3 resource-type binding (Figure 1)\n");
    characterize::fig1(&ctx)?;

    let (s1, s2) = characterize::fig3_vcpu_spread(ctx.seed);
    println!("\nresolution effect: set-1 vCPU spread {:.0}%, set-2 {:.0}%", s1 * 100.0, s2 * 100.0);
    println!("(Takeaway #1: input properties beyond size drive resource usage.)");
    Ok(())
}
