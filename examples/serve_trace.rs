//! End-to-end validation driver (DESIGN.md §4, EXPERIMENTS.md §E2E):
//! serve a full Azure-like trace through the complete three-layer stack —
//! rust coordinator routing predict/update calls through the
//! AOT-compiled Pallas/JAX artifacts on PJRT — on the paper's 16-invoker
//! testbed, and report latency/throughput vs the static-large baseline.
//!
//!     make artifacts && cargo run --release --example serve_trace
//!
//! Falls back to the native learner (with a notice) if artifacts are
//! missing, so the example always runs.

use std::time::Instant;

use shabari::baselines::StaticPolicy;
use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::metrics::from_result;
use shabari::simulator::engine::simulate;
use shabari::simulator::SimConfig;
use shabari::workload::Workload;

fn main() -> anyhow::Result<()> {
    let have_xla = cfg!(feature = "xla")
        && std::path::Path::new("artifacts/manifest.json").exists();
    let acfg = if have_xla {
        println!("learner backend: XLA/PJRT (AOT Pallas/JAX artifacts)");
        AllocatorConfig::xla("artifacts")
    } else {
        println!(
            "learner backend: native (build with --features xla and run \
             `make artifacts` for the XLA path)"
        );
        AllocatorConfig::default()
    };

    let rps = 4.0;
    let duration = 600.0;
    let workload = Workload::build(42, 1.4);
    let trace = workload.trace(rps, duration, 11);
    println!(
        "trace: {} invocations over {duration} s (~{rps} rps), 16 workers x 90 vCPU / 125 GB\n",
        trace.len()
    );

    // Shabari (full system)
    let allocator = ResourceAllocator::new(acfg)?;
    let mut shabari = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(42)));
    let t0 = Instant::now(); // lint:allow(D002): host wall time for the driver report; simulated time comes from the engine
    let res_s = simulate(SimConfig::default(), &mut shabari, trace.clone());
    let wall_s = t0.elapsed().as_secs_f64();
    let ms = from_result("shabari", &res_s);

    // static-large comparison
    let mut static_large = StaticPolicy::large(42);
    let t0 = Instant::now(); // lint:allow(D002): host wall time for the driver report; simulated time comes from the engine
    let res_l = simulate(SimConfig::default(), &mut static_large, trace);
    let wall_l = t0.elapsed().as_secs_f64();
    let ml = from_result("static-large", &res_l);

    println!("{:<28} {:>12} {:>14}", "metric", "shabari", "static-large");
    println!("{:-<56}", "");
    let row = |k: &str, a: String, b: String| println!("{k:<28} {a:>12} {b:>14}");
    row(
        "SLO violations",
        format!("{:.1}%", ms.slo_violation_pct),
        format!("{:.1}%", ml.slo_violation_pct),
    );
    row(
        "wasted vCPUs p50",
        format!("{:.1}", ms.wasted_vcpus.p50),
        format!("{:.1}", ml.wasted_vcpus.p50),
    );
    row(
        "wasted vCPUs p95",
        format!("{:.1}", ms.wasted_vcpus.p95),
        format!("{:.1}", ml.wasted_vcpus.p95),
    );
    row(
        "wasted memory p50 (GB)",
        format!("{:.2}", ms.wasted_mem_gb.p50),
        format!("{:.2}", ml.wasted_mem_gb.p50),
    );
    row(
        "vCPU utilization p50",
        format!("{:.0}%", 100.0 * ms.vcpu_utilization.p50),
        format!("{:.0}%", 100.0 * ml.vcpu_utilization.p50),
    );
    row(
        "mem utilization p50",
        format!("{:.0}%", 100.0 * ms.mem_utilization.p50),
        format!("{:.0}%", 100.0 * ml.mem_utilization.p50),
    );
    row(
        "cold starts",
        format!("{:.1}%", ms.cold_start_pct),
        format!("{:.1}%", ml.cold_start_pct),
    );
    row("mean e2e latency", format!("{:.2}s", ms.mean_e2e_s), format!("{:.2}s", ml.mean_e2e_s));
    row(
        "throughput (completed/s)",
        format!("{:.2}", ms.throughput),
        format!("{:.2}", ml.throughput),
    );
    row("driver wall time", format!("{wall_s:.2}s"), format!("{wall_l:.2}s"));
    row(
        "simulated inv/s (driver)",
        format!("{:.0}", ms.invocations as f64 / wall_s),
        format!("{:.0}", ml.invocations as f64 / wall_l),
    );

    // The qualitative headline must hold end-to-end:
    anyhow::ensure!(
        ms.wasted_vcpus.p50 <= ml.wasted_vcpus.p50,
        "Shabari must waste fewer vCPUs than static-large"
    );
    anyhow::ensure!(
        ms.wasted_mem_gb.p50 <= ml.wasted_mem_gb.p50,
        "Shabari must waste less memory than static-large"
    );
    println!("\nE2E check OK: Shabari right-sizes vs static-large on the same trace.");
    Ok(())
}
