//! Head-to-head allocator comparison on one trace: all six Fig-8 systems
//! at a chosen load.
//!
//!     cargo run --release --example compare_allocators -- --rps 5

use shabari::experiments::common::{run_one, sim_config, Ctx};
use shabari::experiments::e2e::FIG8_POLICIES;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rps = args
        .iter()
        .position(|a| a == "--rps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(4.0);

    let ctx = Ctx { duration_s: 600.0, ..Default::default() };
    let workload = ctx.workload();
    let cfg = sim_config(&ctx);

    println!(
        "{:<16} {:>10} {:>14} {:>14} {:>12} {:>12}",
        "system", "SLO viol", "waste vCPU p50", "waste mem p50", "cpu util", "cold starts"
    );
    println!("{:-<82}", "");
    for name in FIG8_POLICIES {
        let (_, m) = run_one(name, &ctx, &workload, rps, &cfg)?;
        println!(
            "{:<16} {:>9.1}% {:>14.1} {:>11.2} GB {:>11.0}% {:>11.1}%",
            name,
            m.slo_violation_pct,
            m.wasted_vcpus.p50,
            m.wasted_mem_gb.p50,
            100.0 * m.vcpu_utilization.p50,
            m.cold_start_pct,
        );
    }
    println!("\n(rps = {rps}; see `shabari experiment fig8` for the full sweep)");
    Ok(())
}
