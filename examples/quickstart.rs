//! Quickstart: run Shabari on a small Azure-like trace and print the
//! paper's three evaluation metrics.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native learner backend so it runs without artifacts; pass
//! `--xla` (after `make artifacts`) to exercise the production
//! Pallas/JAX/XLA path.

use shabari::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use shabari::coordinator::scheduler::shabari::ShabariScheduler;
use shabari::coordinator::ShabariPolicy;
use shabari::metrics::from_result;
use shabari::simulator::engine::simulate;
use shabari::simulator::{Policy, SimConfig};
use shabari::workload::Workload;

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");

    // 1. Build the Table-1 workload with 1.4x SLOs.
    let workload = Workload::build(42, 1.4);

    // 2. Assemble Shabari: online allocator + cold-start-aware scheduler.
    let cfg = if use_xla { AllocatorConfig::xla("artifacts") } else { AllocatorConfig::default() };
    let backend = cfg.learner_backend;
    let allocator = ResourceAllocator::new(cfg)?;
    let mut shabari = ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(42)));
    println!("policy: {} (backend: {backend:?})", shabari.name());

    // 3. A 5-minute trace at 4 requests/second.
    let trace = workload.trace(4.0, 300.0, 7);
    println!("trace: {} invocations over 300 s", trace.len());

    // 4. Simulate on the paper's 16-invoker testbed.
    let res = simulate(SimConfig::default(), &mut shabari, trace);
    let m = from_result("shabari", &res);

    println!("\n== results ==");
    println!("SLO violations:        {:.1}%", m.slo_violation_pct);
    println!("wasted vCPUs (p50):    {:.1}", m.wasted_vcpus.p50);
    println!("wasted memory (p50):   {:.2} GB", m.wasted_mem_gb.p50);
    println!("vCPU utilization p50:  {:.0}%", 100.0 * m.vcpu_utilization.p50);
    println!("mem utilization p50:   {:.0}%", 100.0 * m.mem_utilization.p50);
    println!("cold starts:           {:.1}%", m.cold_start_pct);
    println!("containers created:    {}", res.containers_created);
    println!("background launches:   {}", res.background_launches);
    Ok(())
}
